"""Multi-device correctness checks, run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set by the caller —
tests/conftest.py — BEFORE python starts, so the main pytest process keeps
its single real device).

Each ``check_*`` function is independent; ``main`` runs those named on the
command line (or all) and prints ``PASS <name>`` / ``FAIL <name>: err``.
"""

import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bucketing import plan_buckets, reduce_gradients
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.compat import shard_map, set_mesh


def _mesh1d(n=None):
    devs = jax.devices()
    n = n or len(devs)
    return Mesh(np.array(devs[:n]), ("data",))


# ---------------------------------------------------------------------------
def check_collectives_numerics():
    """CommRuntime collectives == plain lax collectives, all progress modes."""
    mesh = _mesh1d()
    n = mesh.size
    x = jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4)

    for progress in ("global", "per_vci", "hybrid"):
        def run(x):
            world = CommWorld(num_vcis=4)
            rt = CommRuntime(world, progress=progress, join_every=2)
            c1 = world.create("c1")
            c2 = world.create("c2")
            w = world.create("w", kind="rma")
            ar = rt.all_reduce(x, c1, axis="data")
            ag = rt.all_gather(x, c2, axis="data")
            rs = rt.reduce_scatter(ag, c1, axis="data")
            a2a = rt.all_to_all(
                jnp.broadcast_to(x, (n,) + x.shape), c2, axis="data",
                split_axis=0, concat_axis=1)
            perm = [(i, (i + 1) % n) for i in range(n)]
            sr = rt.sendrecv(x, c1, axis="data", perm=perm)
            acc = rt.accumulate(x, w, axis="data")
            return rt.barrier((ar, ag, rs, a2a, sr, acc))

        f = jax.jit(shard_map(run, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_vma=False))
        ar, ag, rs, a2a, sr, acc = f(x)
        np.testing.assert_allclose(ar, jnp.broadcast_to(x.sum(0), (n, 4)))
        np.testing.assert_allclose(ag.reshape(n, n, 4)[0], x)
        np.testing.assert_allclose(rs, x * n)
        np.testing.assert_allclose(sr, jnp.roll(x, 1, axis=0))
        np.testing.assert_allclose(acc, jnp.broadcast_to(x.sum(0), (n, 4)))
        assert a2a.shape == (n, n, 4)


def check_accumulate_relaxed_matches_ordered():
    """accumulate_ordering=none (§6.3 hint) changes scheduling, not values."""
    mesh = _mesh1d()
    n = mesh.size
    x = jnp.arange(n * 8, dtype=jnp.float32).reshape(n, 8)

    outs = {}
    for ordering in ("rar", "none"):
        def run(x):
            world = CommWorld(num_vcis=4)
            rt = CommRuntime(world, progress="hybrid")
            w = world.create("w", kind="rma", accumulate_ordering=ordering)
            a = rt.accumulate(x, w, axis="data")
            b = rt.accumulate(x * 2, w, axis="data")
            return rt.barrier(a + b)
        f = jax.jit(shard_map(run, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_vma=False))
        outs[ordering] = np.asarray(f(x))
    np.testing.assert_allclose(outs["rar"], outs["none"])


def check_reduce_gradients_matches_pmean():
    """Bucketed VCI reduction == tree-wise pmean, both staging modes."""
    mesh = _mesh1d()
    n = mesh.size
    rng = np.random.default_rng(0)
    tree = {
        "a": jnp.asarray(rng.normal(size=(n, 16, 8)), jnp.float32),
        "b": {"w": jnp.asarray(rng.normal(size=(n, 130)), jnp.float32),
              "s": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)},
    }
    # per-shard leaves keep their leading (1, ...) dim; the mean over 'data'
    # replicates, so the global result is mean-with-keepdims.
    expect = jax.tree_util.tree_map(lambda t: t.mean(0, keepdims=True), tree)

    for staging in ("per_vci", "shared"):
        for progress in ("global", "per_vci", "hybrid"):
            def run(tr):
                world = CommWorld(num_vcis=4)
                rt = CommRuntime(world, progress=progress, join_every=3)
                plan = plan_buckets(tr, 3, align=8)
                red = reduce_gradients(rt, tr, plan, axis="data", mean=True,
                                       staging=staging)
                return rt.barrier(red)
            f = jax.jit(shard_map(
                run, mesh=mesh,
                in_specs=(jax.tree_util.tree_map(lambda _: P("data"), tree),),
                out_specs=jax.tree_util.tree_map(lambda _: P(), tree),
                check_vma=False))
            got = f(tree)
            for g, e in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(expect)):
                np.testing.assert_allclose(g, e, rtol=1e-5, atol=1e-6)


def check_bucket_fastpath_matches_pmean():
    """Every fast-path cell (pack x reduction x plan persistence) must equal
    tree-wise pmean — the numerical acceptance gate for the bucketed fast
    path (persistent CommPlan, pallas/DMA pack, reduce_scatter+all_gather)."""
    from repro.core import get_comm_plan, plan_cache_clear, plan_cache_stats
    from repro.core.bucketing import reduce_gradients as reduce_g

    mesh = _mesh1d()
    n = mesh.size
    rng = np.random.default_rng(7)
    tree = {
        "a": jnp.asarray(rng.normal(size=(n, 16, 8)), jnp.float32),
        "b": {"w": jnp.asarray(rng.normal(size=(n, 130)), jnp.float32),
              "s": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)},
        "c": jnp.asarray(rng.normal(size=(n, 257)), jnp.bfloat16),
    }
    expect = jax.tree_util.tree_map(
        lambda t: jnp.asarray(t, jnp.float32).mean(0, keepdims=True)
        .astype(t.dtype), tree)

    plan_cache_clear()
    for pack in ("xla", "pallas"):
        for reduction in ("all_reduce", "reduce_scatter"):
            for persistent in (True, False):
                def run(tr):
                    cp = get_comm_plan(tr, num_streams=3, num_vcis=4,
                                       pack=pack, persistent=persistent)
                    rt = cp.runtime()
                    red = reduce_g(rt, tr, cp, axis="data", mean=True,
                                   pack=pack, reduction=reduction)
                    return rt.barrier(red)

                f = jax.jit(shard_map(
                    run, mesh=mesh,
                    in_specs=(jax.tree_util.tree_map(lambda _: P("data"),
                                                     tree),),
                    out_specs=jax.tree_util.tree_map(lambda _: P(), tree),
                    check_vma=False))
                got = f(tree)
                for g, e in zip(jax.tree_util.tree_leaves(got),
                                jax.tree_util.tree_leaves(expect)):
                    np.testing.assert_allclose(
                        np.asarray(g, np.float32), np.asarray(e, np.float32),
                        rtol=1e-5, atol=1e-5)
    # the persistent cells must actually have reused cached plans
    assert plan_cache_stats()["hits"] >= 2, plan_cache_stats()


def check_zero1_matches_replicated():
    """ZeRO-1 conformance: 5 steps of ``make_train_step(optimizer="zero1")``
    (reduce_scatter shards -> sharded AdamW -> param all_gather) must match
    the replicated path to fp32 tolerance on the 8-device mesh, for a dense
    config (gemma) AND an MoE config (mixtral). Smoke configs carry f32
    params, so with the default f32 wire the two paths differ only in
    collective summation order."""
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.train.trainer import make_train_step, train_state_init

    mesh = _mesh1d()
    n = mesh.size
    for arch in ("gemma-2b-smoke", "mixtral-8x22b-smoke"):
        cfg = get_config(arch)
        knobs = dict(mesh=mesh, comm="vci", num_streams=4, num_vcis=4,
                     token_impl="data")
        step_rep = make_train_step(cfg, **knobs)
        step_z1 = make_train_step(cfg, optimizer="zero1", **knobs)
        s_rep = train_state_init(cfg, jax.random.PRNGKey(0))
        s_z1 = train_state_init(cfg, jax.random.PRNGKey(0),
                                optimizer="zero1", mesh=mesh, num_streams=4)
        # zero1 optimizer state is genuinely 1/N per rank
        shard_elems = sum(m.size for m in s_z1.opt.m) // n
        full_elems = sum(l.size for l in jax.tree_util.tree_leaves(s_rep.opt.m))
        assert shard_elems < full_elems, (shard_elems, full_elems)

        with set_mesh(mesh):
            jr, jz = jax.jit(step_rep), jax.jit(step_z1)
            for i in range(5):
                batch = synthetic_batch(cfg, 2 * n, 32, seed=i)
                s_rep, m_rep = jr(s_rep, batch)
                s_z1, m_z1 = jz(s_z1, batch)
                for k in ("loss", "grad_norm"):
                    np.testing.assert_allclose(
                        float(m_z1[k]), float(m_rep[k]), rtol=1e-5,
                        err_msg=f"{arch} step {i} metric {k}")
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_leaves_with_path(s_z1.params),
                jax.tree_util.tree_leaves_with_path(s_rep.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-5, atol=1e-6,
                err_msg=f"{arch} param {jax.tree_util.keystr(pa)}")


def check_overlap_matches_post():
    """Bucket-ready overlap scheduling conformance: 5 train steps with
    ``schedule="overlap"`` (each bucket's reduce issued inside the backward
    via its custom_vjp boundary) must match ``schedule="post"`` (one
    post-backward reduction pass) to fp32 tolerance on the 8-device mesh,
    for a dense config (gemma) AND an MoE config (mixtral), for BOTH
    ``optimizer="replicated"`` and ``"zero1"``, including microbatch
    accumulation (the dense configs run accum_steps=2: only the last
    microbatch's backward carries the boundaries, earlier microbatches ride
    in as the carry)."""
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.train.trainer import make_train_step, train_state_init

    mesh = _mesh1d()
    n = mesh.size
    for arch in ("gemma-2b-smoke", "mixtral-8x22b-smoke"):
        cfg = get_config(arch)
        accum = 2 if arch.startswith("gemma") else 1
        for optimizer in ("replicated", "zero1"):
            knobs = dict(mesh=mesh, comm="vci", num_streams=4, num_vcis=4,
                         token_impl="data", accum_steps=accum,
                         optimizer=optimizer)
            states, steps = {}, {}
            for sched in ("post", "overlap"):
                steps[sched] = make_train_step(cfg, schedule=sched, **knobs)
                states[sched] = train_state_init(
                    cfg, jax.random.PRNGKey(0), optimizer=optimizer,
                    mesh=mesh, num_streams=4, schedule=sched)
            with set_mesh(mesh):
                jits = {s: jax.jit(f) for s, f in steps.items()}
                for i in range(5):
                    batch = synthetic_batch(cfg, 2 * n, 32, seed=i)
                    metrics = {}
                    for sched in ("post", "overlap"):
                        states[sched], metrics[sched] = jits[sched](
                            states[sched], batch)
                    for k in ("loss", "grad_norm"):
                        np.testing.assert_allclose(
                            float(metrics["overlap"][k]),
                            float(metrics["post"][k]), rtol=1e-5,
                            err_msg=f"{arch} {optimizer} step {i} "
                                    f"metric {k}")
            for (pa, a), (pb, b) in zip(
                    jax.tree_util.tree_leaves_with_path(
                        states["overlap"].params),
                    jax.tree_util.tree_leaves_with_path(
                        states["post"].params)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=2e-5, atol=1e-6,
                    err_msg=f"{arch} {optimizer} param "
                            f"{jax.tree_util.keystr(pa)}")


def check_vci_train_step_matches_gspmd():
    """comm='vci' (paper mode) and comm='gspmd' produce the same update."""
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.train.trainer import make_train_step, train_state_init

    mesh = _mesh1d()
    n = mesh.size
    cfg = get_config("olmo-1b-smoke")
    batch = synthetic_batch(cfg, 2 * n, 32, seed=1)
    state = train_state_init(cfg, jax.random.PRNGKey(0))

    with set_mesh(mesh):
        ref_step = jax.jit(make_train_step(cfg, mesh=None, comm="gspmd"))
        s_ref, m_ref = ref_step(state, batch)

    for progress in ("hybrid", "per_vci", "global"):
        step = make_train_step(cfg, mesh=mesh, comm="vci", num_streams=4,
                               num_vcis=4, progress=progress,
                               token_impl="data")
        with set_mesh(mesh):
            s_vci, m_vci = jax.jit(step)(state, batch)
        np.testing.assert_allclose(
            float(m_vci["loss"]), float(m_ref["loss"]), rtol=1e-5)
        # bf16 params + different reduction order: one bf16 ULP is
        # 2^-8 ~= 3.9e-3, so rtol must sit above it (seed's 2e-3 flaked on
        # elements exactly one ULP apart); 5e-3 = 1.28 ULP headroom.
        for a, b in zip(jax.tree_util.tree_leaves(s_vci.params),
                        jax.tree_util.tree_leaves(s_ref.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-3, atol=5e-6)


def check_scan_vs_unroll_collective_parity():
    """Roofline HLO parser: scan-over-layers must count L x the collectives
    of one layer — parity with the unrolled version of the same model."""
    from repro.launch.roofline import parse_collectives

    mesh = _mesh1d()
    L, d = 4, 8

    def layer(x, w):
        y = x @ w
        return jax.lax.psum(y, "data")

    def scanned(x, ws):
        def body(c, w):
            return layer(c, w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    def unrolled(x, ws):
        for i in range(L):
            x = layer(x, ws[i])
        return x

    x = jnp.zeros((2, d))
    ws = jnp.zeros((L, d, d))
    spec_in = (P(), P())
    f_s = jax.jit(shard_map(scanned, mesh=mesh, in_specs=spec_in,
                            out_specs=P(), check_vma=False))
    f_u = jax.jit(shard_map(unrolled, mesh=mesh, in_specs=spec_in,
                            out_specs=P(), check_vma=False))
    n = mesh.size
    hlo_s = f_s.lower(x, ws).compile().as_text()
    hlo_u = f_u.lower(x, ws).compile().as_text()
    b_s = sum(op.link_bytes for op in parse_collectives(hlo_s, n))
    b_u = sum(op.link_bytes for op in parse_collectives(hlo_u, n))
    assert b_u > 0, "unrolled model lost its collectives"
    assert abs(b_s - b_u) / b_u < 0.01, (b_s, b_u)


def check_progress_mode_hlo_structure():
    """per_vci emits fewer cross-stream joins than hybrid; all modes keep
    every collective alive (drain prevents DCE)."""
    mesh = _mesh1d()

    def make(progress, join_every=1):
        def run(x):
            world = CommWorld(num_vcis=4)
            rt = CommRuntime(world, progress=progress, join_every=join_every)
            ctxs = [world.create(f"c{i}") for i in range(4)]
            outs = [rt.all_reduce(x + i, c, axis="data")
                    for i, c in enumerate(ctxs)]
            return rt.barrier(sum(outs))
        return jax.jit(shard_map(run, mesh=mesh, in_specs=P("data"),
                                 out_specs=P(), check_vma=False))

    x = jnp.ones((mesh.size, 4))
    for progress in ("global", "per_vci", "hybrid"):
        hlo = make(progress).lower(x).compile().as_text()
        assert hlo.count("all-reduce") >= 4 or "all-reduce" in hlo, progress
    # values identical across modes
    ref = None
    for progress in ("global", "per_vci", "hybrid"):
        val = np.asarray(make(progress)(x))
        if ref is None:
            ref = val
        np.testing.assert_allclose(val, ref)


def check_moe_expert_parallel_all_to_all():
    """The MoE dispatch under an expert-parallel mesh lowers all-to-all or
    equivalent resharding collectives, and numerics match the meshless run."""
    from repro.configs import get_config
    from repro.models.moe import moe_ffn
    from repro.models.transformer import init_params
    from repro.dist.sharding import Sharder

    cfg = get_config("mixtral-8x22b-smoke")  # 4 experts
    mesh = _mesh1d(4)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)

    y_ref, aux_ref = moe_ffn(cfg, x, lp, None, inference=True)

    shard = Sharder(mesh, cfg)
    with set_mesh(mesh):
        f = jax.jit(lambda x, p: moe_ffn(cfg, x, p, shard, inference=True)[0],
                    in_shardings=(NamedSharding(mesh, P("data")), None))
        y_sh = f(x, lp)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)





def check_serve_streams_match_single_stream():
    """Serve-path VCI streams (manual-TP decode on a data x model mesh,
    collectives on per-purpose CommContexts) must produce IDENTICAL tokens
    to the single-device engine, for a dense tied-embedding arch and an
    expert-parallel MoE arch, at num_vcis=1 (everything collides on the
    fallback stream) and num_vcis=8 (dedicated streams). Mixed-length
    batches ride along so left-padded prefill is exercised under TP too.

    The PAGED cells repeat the sweep with the paged KV cache and
    batch_size=2 < #requests, so mid-stream admission (page alloc + the
    shard-aware admission prefill + splice) runs UNDER the mesh — the
    continuous-batching limit this cache lifts — and still with identical
    tokens; the paged pool must also hold fewer resident bytes than the
    full-provision contiguous cache despite the extra page table."""
    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serve.comm import PURPOSES, ServeCommPlan
    from repro.serve.engine import Request, ServeEngine

    devs = np.array(jax.devices()[:8]).reshape(4, 2)
    mesh = Mesh(devs, ("data", "model"))

    for arch in ("olmo-1b-smoke", "mixtral-8x22b-smoke"):
        cfg = get_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))

        def make_requests():
            rng = np.random.default_rng(7)
            return [Request(prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                                dtype=np.int32),
                            max_new_tokens=5)
                    for plen in (5, 9, 3, 7)]

        ref = make_requests()
        solo = ServeEngine(cfg, params, batch_size=4, max_len=48)
        solo.generate(ref)

        for num_vcis in (1, 8):
            plan = ServeCommPlan(num_vcis=num_vcis, token_impl="data")
            eng = ServeEngine(cfg, params, batch_size=4, max_len=48,
                              mesh=mesh, comm_plan=plan)
            got = make_requests()
            eng.generate(got)
            for i, (a, b) in enumerate(zip(got, ref)):
                np.testing.assert_array_equal(
                    a.generated, b.generated,
                    err_msg=f"{arch} num_vcis={num_vcis} request {i}")
            # the plan realized the expected mapping: exhausted pool -> all
            # contexts share the fallback; ample pool -> distinct streams
            indices = set(plan.vci_map().values())
            if num_vcis == 1:
                assert indices == {0}, plan.vci_map()
                assert plan.stats.fallback_hits == len(PURPOSES)
            else:
                assert len(indices) == len(PURPOSES), plan.vci_map()
                assert plan.stats.fallback_hits == 0

            # paged cells: same tokens through page-table indirection, with
            # mid-stream admission exercised under the mesh
            plan_p = ServeCommPlan(num_vcis=num_vcis, token_impl="data")
            eng_p = ServeEngine(cfg, params, batch_size=2, max_len=48,
                                mesh=mesh, comm_plan=plan_p, paged=True,
                                page_size=8, num_pages=11)
            assert eng_p._paged and eng_p._can_admit, \
                "paged engine must admit mid-stream under the mesh"
            got_p = make_requests()
            eng_p.generate(got_p)
            for i, (a, b) in enumerate(zip(got_p, ref)):
                np.testing.assert_array_equal(
                    a.generated, b.generated,
                    err_msg=f"{arch} paged num_vcis={num_vcis} request {i}")
            owner = np.asarray(eng_p._owner)
            assert (owner[1:] == -1).all(), f"pages leaked: {owner}"
            assert eng_p.cache_bytes_resident < solo.cache_bytes_resident, (
                eng_p.cache_bytes_resident, solo.cache_bytes_resident)


def check_vci_trainer_lowers_production_mesh():
    """The paper-mode (shard_map + VCI buckets) trainer must lower/compile
    on the full production mesh (run with 256+ virtual devices)."""
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    from repro.data.pipeline import batch_spec
    from repro.launch import inputs as I
    from repro.launch.mesh import make_production_mesh
    from repro.train.trainer import make_train_step

    cfg = get_config("olmo-1b")
    shape = INPUT_SHAPES["train_4k"]
    mesh = make_production_mesh()
    for progress in ("global", "per_vci", "hybrid"):
        step = make_train_step(cfg, mesh=mesh, comm="vci", num_streams=8,
                               num_vcis=8, progress=progress)
        with set_mesh(mesh):
            jax.jit(step).lower(I.train_state_struct(cfg),
                                batch_spec(cfg, shape, mesh)).compile()


def check_flash_decode_sequence_sharded():
    """partial_attention + combine_partials (flash-decode LSE combine) over
    a sequence-sharded KV cache == single-device decode_attention — the
    long-context decode path where the cache is the only shardable state."""
    from repro.configs import get_config
    from repro.models.attention import (KVCache, combine_partials,
                                        decode_attention, partial_attention)

    mesh = _mesh1d()
    n = mesh.size
    cfg = get_config("yi-9b-smoke")
    b, s, kv, hd = 2, 64, cfg.num_kv_heads, cfg.head_dim
    h = cfg.num_heads
    assert s % n == 0
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    length = 50  # only the first 50 slots are valid

    # reference: full-cache decode attention
    cache = KVCache(kc, vc, jnp.asarray(length, jnp.int32), False)
    ref = decode_attention(cfg, q, cache)

    # distributed: sequence shards + LSE combine across the mesh
    def shard_attn(q, kcs, vcs, start):
        idx = start[0] + jnp.arange(kcs.shape[1])
        valid = idx < length
        out, m, l = partial_attention(q, kcs, vcs, valid)
        outs = jax.lax.all_gather(out, "data")            # (n,B,1,H,hd)
        ms = jax.lax.all_gather(m, "data")                # (n,B,H,1,1)
        ls = jax.lax.all_gather(l, "data")
        return combine_partials(outs, ms, ls)

    starts = jnp.arange(n, dtype=jnp.int32)[:, None] * (s // n)
    f = jax.jit(shard_map(
        shard_attn, mesh=mesh,
        in_specs=(P(), P(None, "data"), P(None, "data"), P("data")),
        out_specs=P(), check_vma=False))
    got = f(q, kc, vc, starts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


CHECKS = {k[len("check_"):]: v for k, v in list(globals().items())
          if k.startswith("check_")}


def main():
    names = sys.argv[1:] or list(CHECKS)
    failed = 0
    for name in names:
        try:
            CHECKS[name]()
            print(f"PASS {name}", flush=True)
        except Exception:
            failed += 1
            print(f"FAIL {name}:\n{traceback.format_exc()}", flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
