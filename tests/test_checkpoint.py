"""Checkpoint roundtrip tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.train.trainer import train_state_init


def test_roundtrip_train_state(tmp_path):
    cfg = get_config("olmo-1b-smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, state, metadata={"arch": cfg.name})
    assert latest_step(str(tmp_path)) == 7
    restored = load_checkpoint(str(tmp_path), 7, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_multiple(tmp_path):
    tree = {"x": jnp.ones((2,))}
    for s in (1, 12, 5):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 12
    assert latest_step(str(tmp_path / "nope")) is None


def test_tree_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.ones((2,))})
    with pytest.raises(ValueError, match="mismatch"):
        load_checkpoint(str(tmp_path), 0, {"y": jnp.ones((2,))})


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.ones((2,))})
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(str(tmp_path), 0, {"x": jnp.ones((3,))})


def test_dtype_cast_on_load(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.ones((2,), jnp.float32)})
    out = load_checkpoint(str(tmp_path), 0, {"x": jnp.ones((2,), jnp.bfloat16)})
    assert out["x"].dtype == jnp.bfloat16
