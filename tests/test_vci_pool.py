"""Unit tests for the VCI pool and CommContext registry (paper §4.2)."""

import pytest

from repro.core.comm import CommContext, CommWorld
from repro.core.vci import POLICIES, VCI, VCIPool


class TestVCIPool:
    def test_fcfs_assigns_distinct_then_fallback(self):
        pool = VCIPool(num_vcis=4, policy="fcfs")
        got = [pool.acquire(f"c{i}").index for i in range(6)]
        # 3 free interfaces (0 is the fallback), then fallback hits
        assert sorted(got[:3]) == [1, 2, 3]
        assert got[3:] == [VCIPool.FALLBACK] * 3
        assert pool.stats.fallback_hits == 3

    def test_release_returns_vci_to_pool(self):
        pool = VCIPool(num_vcis=2, policy="fcfs")
        v = pool.acquire("a")
        assert v.index == 1
        assert pool.acquire("b").index == VCIPool.FALLBACK  # exhausted
        pool.release("a")
        assert pool.acquire("c").index == 1                  # recycled

    def test_fallback_never_released_to_pool(self):
        pool = VCIPool(num_vcis=2, policy="fcfs")
        pool.acquire("a")            # takes 1
        pool.acquire("b")            # fallback
        pool.release("b")
        # releasing a fallback-mapped context must not free interface 0
        assert pool.acquire("c").index == VCIPool.FALLBACK

    def test_round_robin_cycles_nonfallback(self):
        pool = VCIPool(num_vcis=3, policy="round_robin")
        got = [pool.acquire(f"c{i}").index for i in range(5)]
        assert got == [1, 2, 1, 2, 1]

    def test_hash_is_deterministic(self):
        a = VCIPool(num_vcis=8, policy="hash")
        b = VCIPool(num_vcis=8, policy="hash")
        for name in ("alpha", "beta", "gamma"):
            assert a.acquire(name).index == b.acquire(name).index

    def test_hinted_policy(self):
        pool = VCIPool(num_vcis=3, policy="hinted")
        assert pool.acquire("bg").index == VCIPool.FALLBACK      # unhinted
        h1 = pool.acquire("hot1", hint="dedicated").index
        h2 = pool.acquire("hot2", hint="dedicated").index
        assert {h1, h2} == {1, 2}  # dedicated interfaces, order unspecified
        assert pool.acquire("hot3", hint="dedicated").index == VCIPool.FALLBACK

    def test_shared_hint_forces_fallback(self):
        pool = VCIPool(num_vcis=4, policy="fcfs")
        assert pool.acquire("x", hint="shared").index == VCIPool.FALLBACK

    def test_double_acquire_rejected(self):
        pool = VCIPool(num_vcis=2)
        pool.acquire("a")
        with pytest.raises(KeyError):
            pool.acquire("a")

    def test_invalid_args(self):
        with pytest.raises(ValueError):
            VCIPool(num_vcis=0)
        with pytest.raises(ValueError):
            VCIPool(num_vcis=2, policy="nope")

    def test_stats_track_max_contexts(self):
        pool = VCIPool(num_vcis=2, policy="fcfs")
        for i in range(4):
            pool.acquire(f"c{i}")
        # one on VCI 1, three on the fallback
        assert pool.stats.max_contexts_per_vci == 3
        assert pool.stats.acquires == 4

    def test_hash_on_index_zero_is_not_a_fallback_hit(self):
        """A hash assignment landing on VCI 0 is a normal mapping, not pool
        exhaustion — recording it as a fallback skewed the mapping-mismatch
        benchmark (regression for the vci.py stats miscount)."""
        pool = VCIPool(num_vcis=2, policy="hash")
        landed_on_zero = 0
        for i in range(32):
            idx = pool.acquire(f"ctx{i}").index
            landed_on_zero += int(idx == VCIPool.FALLBACK)
        assert landed_on_zero > 0, "need at least one hash hit on VCI 0"
        assert pool.stats.fallback_hits == 0

    def test_round_robin_never_counts_fallback(self):
        pool = VCIPool(num_vcis=4, policy="round_robin")
        for i in range(12):
            pool.acquire(f"c{i}")
        assert pool.stats.fallback_hits == 0

    @pytest.mark.parametrize("policy", POLICIES)
    def test_single_vci_pool_counts_fallback(self, policy):
        """num_vcis=1 is permanent exhaustion under EVERY policy (a hash
        landing on 0 % 1 is not a free assignment there)."""
        pool = VCIPool(num_vcis=1, policy=policy)
        pool.acquire("a")
        assert pool.stats.fallback_hits == 1, policy

    def test_hinted_unhinted_share_without_fallback_hit(self):
        """Unhinted contexts under the hinted policy share VCI 0 by design;
        only a 'dedicated' request against an exhausted pool is a hit."""
        pool = VCIPool(num_vcis=2, policy="hinted")
        pool.acquire("bg")                       # unhinted -> shares, no hit
        pool.acquire("hot", hint="dedicated")    # gets VCI 1
        assert pool.stats.fallback_hits == 0
        pool.acquire("hot2", hint="dedicated")   # exhausted -> genuine hit
        assert pool.stats.fallback_hits == 1

    def test_shared_hint_counts_fallback(self):
        pool = VCIPool(num_vcis=4, policy="fcfs")
        pool.acquire("x", hint="shared")
        assert pool.stats.fallback_hits == 1

    def test_release_decrements_live_contexts(self):
        """max_contexts_per_vci must reflect LIVE contexts: releasing a
        context returns its slot in the per-VCI occupancy map."""
        pool = VCIPool(num_vcis=2, policy="fcfs")
        for i in range(4):
            pool.acquire(f"c{i}")    # one on VCI 1, three on the fallback
        assert pool.stats.max_contexts_per_vci == 3
        pool.release("c1")           # fallback occupant
        pool.release("c2")           # fallback occupant
        assert pool.stats.max_contexts_per_vci == 1
        assert pool.stats.releases == 2
        pool.release("c0")           # VCI 1 occupant
        pool.release("c3")           # last fallback occupant
        assert pool.stats.max_contexts_per_vci == 0
        assert pool.stats.acquires == 4 and pool.stats.releases == 4

    @pytest.mark.parametrize("policy", POLICIES)
    def test_indices_always_in_range(self, policy):
        pool = VCIPool(num_vcis=4, policy=policy)
        for i in range(20):
            idx = pool.acquire(f"c{i}", hint="dedicated").index
            assert 0 <= idx < 4


class TestCommWorld:
    def test_world_holds_fallback(self):
        w = CommWorld(num_vcis=4)
        assert w.world.vci.index == VCIPool.FALLBACK

    def test_create_and_free_cycles_vcis(self):
        w = CommWorld(num_vcis=3)
        c1 = w.create("a")
        c2 = w.create("b")
        assert {c1.vci.index, c2.vci.index} == {1, 2}
        c3 = w.create("c")
        assert c3.vci.index == VCIPool.FALLBACK   # Fig. 17 collision
        w.free(c1)
        c4 = w.create("d")
        assert c4.vci.index == c1.vci.index

    def test_vci_pinning_is_endpoint_mode(self):
        w = CommWorld(num_vcis=4)
        c = w.create("ep", vci=3)
        assert c.pinned and c.vci.index == 3
        # pinning bypasses the pool: the pool can still hand out vci 3
        got = {w.create(f"x{i}").vci.index for i in range(3)}
        assert 3 in got
        with pytest.raises(ValueError):
            w.create("bad", vci=99)

    def test_split_creates_subcontexts(self):
        w = CommWorld(num_vcis=8)
        parent = w.create("p", kind="rma", accumulate_ordering="none")
        subs = w.split(parent, 3)
        assert len(subs) == 3
        assert all(s.kind == "rma" for s in subs)
        assert all(s.accumulate_ordering == "none" for s in subs)
        assert len({s.vci.index for s in subs}) == 3  # independent streams

    def test_kind_validation(self):
        with pytest.raises(AssertionError):
            CommContext("x", VCI(0), kind="bogus")
        with pytest.raises(AssertionError):
            CommContext("x", VCI(0), kind="rma", accumulate_ordering="bogus")

    def test_duplicate_name_rejected(self):
        w = CommWorld()
        w.create("dup")
        with pytest.raises(KeyError):
            w.create("dup")
