"""Multi-device integration tests (subprocess: 8 virtual CPU devices).

Each test shells out to tests/_multidev_checks.py with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps the single real device (per the dry-run isolation rule).
"""

import pytest

pytestmark = pytest.mark.multidev


def _run(multidev, name, devices=8):
    r = multidev("_multidev_checks.py", name, devices=devices)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert f"PASS {name}" in r.stdout


def test_collectives_numerics(multidev):
    _run(multidev, "collectives_numerics")


def test_accumulate_relaxed_matches_ordered(multidev):
    _run(multidev, "accumulate_relaxed_matches_ordered")


def test_reduce_gradients_matches_pmean(multidev):
    _run(multidev, "reduce_gradients_matches_pmean")


def test_bucket_fastpath_matches_pmean(multidev):
    """pack (xla|pallas) x reduction (ar|rs+ag) x plan persistence == pmean."""
    _run(multidev, "bucket_fastpath_matches_pmean")


def test_overlap_matches_post(multidev):
    """schedule='overlap' (reduces issued inside the backward, bucket-ready)
    == schedule='post' to fp32 tolerance: dense + MoE, replicated + zero1,
    including microbatch accumulation."""
    _run(multidev, "overlap_matches_post")


@pytest.mark.slow
def test_vci_train_step_matches_gspmd(multidev):
    _run(multidev, "vci_train_step_matches_gspmd")


def test_scan_vs_unroll_collective_parity(multidev):
    _run(multidev, "scan_vs_unroll_collective_parity")


def test_progress_mode_hlo_structure(multidev):
    _run(multidev, "progress_mode_hlo_structure")


def test_moe_expert_parallel_all_to_all(multidev):
    _run(multidev, "moe_expert_parallel_all_to_all", devices=4)


def test_serve_streams_match_single_stream(multidev):
    """Manual-TP decode on VCI streams == single-device tokens (dense+MoE),
    with the realized VCI mapping checked at pool sizes 1 and 8 — for the
    contiguous AND the paged KV cache, the latter with mid-stream admission
    running under the mesh."""
    _run(multidev, "serve_streams_match_single_stream")


@pytest.mark.slow
def test_vci_trainer_lowers_production_mesh(multidev):
    _run(multidev, "vci_trainer_lowers_production_mesh", devices=512)


def test_flash_decode_sequence_sharded(multidev):
    _run(multidev, "flash_decode_sequence_sharded")
