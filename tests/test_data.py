"""Synthetic data pipeline tests."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import (
    PAD_LABEL,
    batch_spec,
    synthetic_batch,
    synthetic_batches,
)
from repro.configs.base import INPUT_SHAPES


def test_deterministic():
    cfg = get_config("olmo-1b-smoke")
    a = synthetic_batch(cfg, 4, 32, seed=3, step=5)
    b = synthetic_batch(cfg, 4, 32, seed=3, step=5)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = synthetic_batch(cfg, 4, 32, seed=3, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_config("olmo-1b-smoke")
    b = synthetic_batch(cfg, 2, 16, seed=0)
    # label[t] is the NEXT token: check the overlap region token[1:]==label[:-1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_successor_structure_learnable():
    """>= 80% of transitions follow the +stride successor rule (noise=0.1)."""
    cfg = get_config("olmo-1b-smoke")
    b = synthetic_batch(cfg, 8, 256, seed=1)
    t = b["tokens"]
    succ = (t[:, :-1] + 7) % cfg.vocab_size
    frac = (t[:, 1:] == succ).mean()
    assert frac > 0.8


def test_vlm_batch():
    cfg = get_config("phi-3-vision-4.2b-smoke")
    S = 48
    b = synthetic_batch(cfg, 2, S, seed=0)
    P = cfg.num_patches
    assert b["tokens"].shape == (2, S - P)
    assert b["image_embeds"].shape[:2] == (2, P)
    assert b["labels"].shape == (2, S)
    assert (b["labels"][:, :P] == PAD_LABEL).all()   # image positions masked
    assert (b["labels"][:, P:] != PAD_LABEL).all()


def test_audio_batch():
    cfg = get_config("musicgen-large-smoke")
    b = synthetic_batch(cfg, 2, 16, seed=0)
    assert b["tokens"].shape == (2, cfg.num_codebooks, 16)
    assert b["labels"].shape == (2, cfg.num_codebooks, 16)


def test_iterator_advances():
    cfg = get_config("olmo-1b-smoke")
    it = synthetic_batches(cfg, 2, 8, seed=0)
    b0, b1 = next(it), next(it)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_batch_spec_covers_all_inputs(shape_name):
    shape = INPUT_SHAPES[shape_name]
    for arch in ("olmo-1b", "phi-3-vision-4.2b", "musicgen-large"):
        cfg = get_config(arch)
        spec = batch_spec(cfg, shape)
        assert "tokens" in spec
        if shape.kind == "train":
            assert "labels" in spec
        if cfg.modality == "vlm" and shape.kind != "decode":
            assert "image_embeds" in spec
