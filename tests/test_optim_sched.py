"""Schedule + loss unit tests."""

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import PAD_LABEL
from repro.optim.schedule import cosine_schedule, linear_warmup
from repro.train.losses import cross_entropy, total_loss


def test_linear_warmup():
    assert float(linear_warmup(0, peak=1.0, warmup_steps=10)) < 0.2
    np.testing.assert_allclose(
        float(linear_warmup(9, peak=2.0, warmup_steps=10)), 2.0)
    np.testing.assert_allclose(
        float(linear_warmup(100, peak=2.0, warmup_steps=10)), 2.0)


def test_cosine_schedule_shape():
    peak, ws, ts = 1.0, 10, 110
    vals = [float(cosine_schedule(s, peak=peak, warmup_steps=ws,
                                  total_steps=ts)) for s in range(0, ts, 5)]
    assert vals[1] <= peak + 1e-6
    assert max(vals) <= peak + 1e-6
    # decays monotonically after warmup
    post = vals[3:]
    assert all(a >= b - 1e-9 for a, b in zip(post, post[1:]))
    # floors at floor_ratio * peak
    end = float(cosine_schedule(ts, peak=peak, warmup_steps=ws, total_steps=ts))
    np.testing.assert_allclose(end, 0.1 * peak, rtol=1e-5)


def test_cross_entropy_uniform_logits():
    V = 16
    logits = jnp.zeros((2, 4, V))
    labels = jnp.zeros((2, 4), jnp.int32)
    s, n = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(s) / float(n), np.log(V), rtol=1e-6)


def test_cross_entropy_masks_pad():
    V = 8
    logits = jnp.zeros((1, 4, V))
    labels = jnp.array([[1, PAD_LABEL, 2, PAD_LABEL]], jnp.int32)
    s, n = cross_entropy(logits, labels)
    assert int(n) == 2
    np.testing.assert_allclose(float(s), 2 * np.log(V), rtol=1e-6)


def test_total_loss_adds_moe_aux():
    cfg = get_config("mixtral-8x22b-smoke")
    logits = jnp.zeros((1, 4, cfg.vocab_size))
    labels = jnp.zeros((1, 4), jnp.int32)
    aux = {"load_balance": jnp.float32(2.0 * cfg.num_layers),
           "router_z": jnp.float32(1.0 * cfg.num_layers)}
    loss, metrics = total_loss(cfg, logits, labels, aux)
    assert float(loss) > float(metrics["ce"])
    dense = get_config("olmo-1b-smoke")
    loss_d, m_d = total_loss(dense, logits, labels, aux)
    np.testing.assert_allclose(float(loss_d), float(m_d["ce"]))
