"""Guard the assigned architecture configs against drift.

Every number below is from the assignment table (citations in each config
module). If a config module changes these, the reproduction is no longer
faithful — these tests are the contract.
"""

import pytest

from repro.configs import (
    ARCH_IDS,
    INPUT_SHAPES,
    all_configs,
    config_for_shape,
    get_config,
)

# arch: (L, d_model, H, kv, d_ff, vocab, family)
ASSIGNED = {
    "gemma-2b": (18, 2048, 8, 1, 16384, 256000, "dense"),
    "yi-9b": (48, 4096, 32, 4, 11008, 64000, "dense"),
    "command-r-35b": (40, 8192, 64, 8, 22528, 256000, "dense"),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000, "hybrid"),
    "mamba2-780m": (48, 1536, 0, 0, 0, 50280, "ssm"),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064, "vlm"),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768, "moe"),
    "olmo-1b": (16, 2048, 16, 16, 8192, 50304, "dense"),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000, "moe"),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048, "audio"),
}

# published parameter counts (total, rough band) to sanity-check param_count()
PUBLISHED_PARAMS = {
    "gemma-2b": (2.0e9, 3.2e9),
    "yi-9b": (8.0e9, 10e9),
    "command-r-35b": (30e9, 40e9),
    "zamba2-7b": (6.3e9, 8.5e9),
    "mamba2-780m": (0.6e9, 0.95e9),
    "phi-3-vision-4.2b": (3.3e9, 4.6e9),
    "mixtral-8x22b": (120e9, 150e9),
    "olmo-1b": (0.9e9, 1.5e9),
    "arctic-480b": (400e9, 520e9),
    "musicgen-large": (2.5e9, 3.6e9),  # MusicGen-large is 3.3B total
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_numbers_exact(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v, fam = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    if h:
        assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.family == fam
    assert cfg.source, f"{arch} missing citation"


def test_family_specifics():
    assert get_config("gemma-2b").head_dim == 256
    assert get_config("gemma-2b").hidden_act == "gelu"         # GeGLU
    assert get_config("gemma-2b").num_kv_heads == 1            # MQA
    assert get_config("olmo-1b").norm == "nonparametric"
    assert get_config("command-r-35b").use_bias is False
    mix = get_config("mixtral-8x22b")
    assert mix.moe.num_experts == 8 and mix.moe.top_k == 2
    assert mix.sliding_window is not None                       # SWA native
    arc = get_config("arctic-480b")
    assert arc.moe.num_experts == 128 and arc.moe.top_k == 2
    assert arc.moe.dense_residual
    zam = get_config("zamba2-7b")
    assert zam.ssm.d_state == 64 and zam.hybrid_attn_every > 0
    mam = get_config("mamba2-780m")
    assert mam.ssm.d_state == 128
    mus = get_config("musicgen-large")
    assert mus.num_codebooks == 4 and mus.modality == "audio"
    phi = get_config("phi-3-vision-4.2b")
    assert phi.modality == "vlm" and phi.num_patches > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_in_published_band(arch):
    cfg = get_config(arch)
    lo, hi = PUBLISHED_PARAMS[arch]
    n = cfg.param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduction_rules(arch):
    s = get_config(arch + "-smoke")
    assert s.num_layers <= 2
    assert s.d_model <= 512
    if s.moe is not None:
        assert s.moe.num_experts <= 4
    assert s.family == get_config(arch).family


def test_moe_active_params_much_smaller():
    for arch in ("mixtral-8x22b", "arctic-480b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.55 * cfg.param_count()


def test_long500k_policy():
    """long_500k must resolve to a sub-quadratic config for every arch."""
    for arch in ARCH_IDS:
        cfg = config_for_shape(arch, "long_500k")
        ok = (cfg.family == "ssm"
              or (cfg.sliding_window is not None
                  and cfg.sliding_window <= 8192)
              or cfg.family == "hybrid")
        assert ok, f"{arch} resolves to quadratic attention at 500k: {cfg.name}"


def test_input_shapes_assigned():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_all_configs_resolve():
    cfgs = all_configs()
    assert len(cfgs) == 10
    assert get_config("yi-9b-swa4096").sliding_window == 4096
    with pytest.raises(KeyError):
        get_config("not-a-model")
