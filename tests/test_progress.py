"""Unit tests for ordering tokens and progress models (paper §4.1/§4.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.progress import (
    GLOBAL_STREAM,
    ProgressEngine,
    after,
    after_data,
    fresh_token,
    join_tokens,
    token_after_data,
)


class TestTokens:
    def test_after_preserves_value(self):
        x = jnp.arange(6.0).reshape(2, 3)
        t = fresh_token()
        np.testing.assert_array_equal(after(x, t), x)

    def test_after_data_is_numeric_noop(self):
        x = jnp.arange(6.0).reshape(2, 3)
        t = fresh_token()
        np.testing.assert_array_equal(after_data(x, t), x)

    def test_token_after_data_tracks_dependency_without_value_change(self):
        x = jnp.full((4,), 3.25)
        t0 = fresh_token()
        t1 = token_after_data(t0, x)
        assert float(t1) == 0.0  # structurally dependent, numerically zero

    def test_join_tokens_identity_values(self):
        toks = tuple(jnp.float32(0.0) for _ in range(3))
        out = join_tokens(toks)
        assert len(out) == 3

    def test_after_creates_hlo_dependency(self):
        """optimization_barrier must survive in the lowered HLO."""
        def f(x, t):
            return after(x, t)
        hlo = jax.jit(f).lower(jnp.zeros((4,)), fresh_token()).as_text()
        assert "opt-barrier" in hlo or "optimization_barrier" in hlo


class TestProgressEngine:
    def test_global_mode_single_token(self):
        eng = ProgressEngine(mode="global")
        eng.token(0)
        eng.token(3)
        eng.token(7)
        assert list(eng._tokens) == [GLOBAL_STREAM]

    def test_per_vci_mode_distinct_tokens(self):
        eng = ProgressEngine(mode="per_vci")
        for i in (0, 3, 7):
            eng.token(i)
        assert sorted(eng._tokens) == [0, 3, 7]
        assert eng.joins == 0

    def test_hybrid_joins_every_k(self):
        eng = ProgressEngine(mode="hybrid", join_every=3)
        x = jnp.zeros((2,))
        for i in range(9):
            v = eng.enter(i % 4, x)
            eng.complete(i % 4, v)
        assert eng.issued == 9
        assert eng.joins == 3  # 9 issues / join_every=3

    def test_per_vci_never_joins(self):
        eng = ProgressEngine(mode="per_vci", join_every=1)
        x = jnp.zeros((2,))
        for i in range(5):
            eng.complete(i, eng.enter(i, x))
        assert eng.joins == 0

    def test_complete_advances_token(self):
        eng = ProgressEngine(mode="per_vci")
        t0 = eng.token(0)
        eng.complete(0, jnp.ones((3,)))
        assert eng.token(0) is not t0

    def test_drain_joins_all(self):
        eng = ProgressEngine(mode="per_vci")
        x = jnp.arange(4.0)
        for i in range(3):
            eng.complete(i, eng.enter(i, x))
        out = eng.drain(x)
        np.testing.assert_array_equal(out, x)
        assert eng.joins == 1  # drain performs one global round

    def test_data_impl_numerics_identical(self):
        eng = ProgressEngine(mode="hybrid", join_every=2, token_impl="data")
        x = jnp.arange(5.0)
        vals = []
        for i in range(4):
            v = eng.enter(i % 2, x)
            eng.complete(i % 2, v)
            vals.append(v)
        for v in vals:
            np.testing.assert_allclose(v, x)

    def test_invalid_mode(self):
        with pytest.raises(ValueError):
            ProgressEngine(mode="nope")
        with pytest.raises(ValueError):
            ProgressEngine(token_impl="nope")

    @pytest.mark.parametrize("mode", ["global", "per_vci", "hybrid"])
    @pytest.mark.parametrize("impl", ["barrier", "data"])
    def test_modes_numerically_transparent_under_jit(self, mode, impl):
        """Whatever the progress model, payload values are unchanged."""
        def f(x):
            eng = ProgressEngine(mode=mode, join_every=2, token_impl=impl)
            out = []
            for i in range(4):
                v = eng.enter(i, x + i)
                eng.complete(i, v)
                out.append(v)
            return eng.drain(sum(out))
        x = jnp.arange(4, dtype=jnp.float32)
        expect = sum(x + i for i in range(4))
        np.testing.assert_allclose(jax.jit(f)(x), expect, rtol=1e-6)
