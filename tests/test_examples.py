"""Every example script must run end-to-end (subprocess smokes)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(name, *args, devices=None, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", name), *args],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=timeout)


@pytest.mark.slow
def test_quickstart():
    r = run_example("quickstart.py")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "generated[3]" in r.stdout


def test_stencil_halo():
    r = run_example("stencil_halo.py", devices=4)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_bspmm_accumulate():
    r = run_example("bspmm_accumulate.py", devices=8)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_ebms_bands():
    r = run_example("ebms_bands.py", devices=8)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_serve_batch():
    r = run_example("serve_batch.py")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_train_e2e_tiny():
    r = run_example("train_e2e.py", "--tiny", "--steps", "15")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "checkpoint:" in r.stdout
