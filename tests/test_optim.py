"""AdamW + schedule unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim import schedule


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(4, 3)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adamw_init(params)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1

    m = np.zeros_like(p0)
    v = np.zeros_like(p0)
    p = p0.copy()
    cur = params
    for t in range(1, 4):
        g = rng.normal(size=p0.shape).astype(np.float32) * 0.1
        cur, state, aux = adamw_update(
            {"w": jnp.asarray(g)}, state, cur, lr=jnp.float32(lr),
            b1=b1, b2=b2, eps=eps, weight_decay=wd, max_grad_norm=None)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        p = p - lr * (mhat / (np.sqrt(vhat) + eps) + wd * p)
        np.testing.assert_allclose(np.asarray(cur["w"]), p, rtol=1e-5,
                                   atol=1e-6)


def test_weight_decay_skips_vectors():
    """1-D params (norm scales, biases) get no decay."""
    params = {"scale": jnp.ones((8,)), "w": jnp.ones((8, 8))}
    state = adamw_init(params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(zero_g, state, params, lr=jnp.float32(0.1),
                               max_grad_norm=None)
    np.testing.assert_array_equal(new_p["scale"], params["scale"])  # no decay
    assert not np.allclose(new_p["w"], params["w"])                 # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    # norm = sqrt(3*16 + 4*9) = sqrt(84)
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(84), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the cap: untouched
    small, norm2 = clip_by_global_norm(
        jax.tree_util.tree_map(lambda x: x * 1e-3, g), 1.0)
    np.testing.assert_allclose(np.asarray(small["a"]), 4e-3, rtol=1e-6)


def test_moment_dtype_configurable():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert st.m["w"].dtype == jnp.bfloat16
    assert st.v["w"].dtype == jnp.bfloat16


def test_schedules():
    fns = [n for n in dir(schedule) if not n.startswith("_")]
    assert fns, "schedule module is empty"
