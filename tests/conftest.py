"""Shared test configuration.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device. Multi-device
checks run in subprocesses (tests/_multidev_checks.py) that set the flag
themselves before importing jax.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidev(script: str, *args: str, devices: int = 8,
                 timeout: int = 900) -> subprocess.CompletedProcess:
    """Run a helper script in a subprocess with N virtual host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.fixture(scope="session")
def multidev():
    return run_multidev


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
