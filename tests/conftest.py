"""Shared test configuration.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device. Multi-device
checks run in subprocesses (tests/_multidev_checks.py) that set the flag
themselves before importing jax.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# The one multi-device topology every subprocess-based test pins: 8 virtual
# host devices (the production-ablation mesh size that all EXPERIMENTS.md
# numbers quote). Benchmarks and _multidev_checks both inherit it through
# the fixtures below.
MULTIDEV_DEVICES = 8


def multidev_env(devices: int = MULTIDEV_DEVICES) -> dict:
    """Environment pinning XLA_FLAGS to N virtual host devices + PYTHONPATH."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def run_multidev(script: str, *args: str, devices: int = MULTIDEV_DEVICES,
                 timeout: int = 900) -> subprocess.CompletedProcess:
    """Run a helper script in a subprocess with N virtual host devices."""
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", script), *args],
        capture_output=True, text=True, timeout=timeout,
        env=multidev_env(devices))


@pytest.fixture(scope="session")
def multidev():
    return run_multidev


@pytest.fixture(scope="session")
def xla_multidev_env():
    """The pinned 8-device XLA_FLAGS environment, for subprocess tests that
    launch their own commands (e.g. benchmark smoke runs)."""
    return multidev_env()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers",
        "multidev: runs a subprocess check on the 8-virtual-device CPU mesh "
        "(tests/_multidev_checks.py via the multidev fixture); part of the "
        "default tier-1 run — select with -m multidev, skip with "
        "-m 'not multidev'")
