"""Benchmark smoke runs under pytest: the perf code must EXECUTE, not just
import. ``benchmarks.run --smoke`` clamps every timing loop to 2 iterations
(BENCH_SMOKE=1), so a full benchmark module runs end-to-end in CI time.
"""

import json
import subprocess
import sys

import pytest

from conftest import REPO, multidev_env


def _run_bench(tmp_path, *argv, timeout=1200):
    env = multidev_env()
    env["BENCH_SMOKE"] = "1"
    env["BENCH_JSON_DIR"] = str(tmp_path)  # keep committed artifacts intact
    return subprocess.run(
        [sys.executable, "-m", *argv], capture_output=True, text=True,
        cwd=REPO, env=env, timeout=timeout)


@pytest.mark.slow
def test_bucket_path_smoke(tmp_path):
    """The 3-knob ablation (now incl. the zero1 cells) runs and emits a
    well-formed BENCH json whose wire-byte summary shows the ZeRO-1 claim:
    grad reduce_scatter + param all_gather move ≲ 0.55x the bytes of the
    f32 gradient all_reduce (bf16 wire, fp32 master shards)."""
    r = _run_bench(tmp_path, "benchmarks.bucket_path", "--devices", "8")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    path = tmp_path / "BENCH_bucket_path.json"
    assert path.is_file(), r.stdout
    doc = json.loads(path.read_text())
    assert len(doc["rows"]) == 12, "2 packs x 3 reductions x 2 plan modes"
    cells = {(row["pack"], row["reduction"], row["plan"])
             for row in doc["rows"]}
    assert ("xla", "all_reduce", "per_step") in cells
    assert ("pallas", "reduce_scatter", "persistent") in cells
    assert ("pallas", "zero1", "persistent") in cells
    s = doc["summary"]
    assert s["seed_config"] == {"pack": "xla", "reduction": "all_reduce",
                                "plan": "per_step"}
    assert s["fast_config"]["plan"] == "persistent"
    assert s["fast_ms_per_step"] > 0 and s["seed_ms_per_step"] > 0
    # the acceptance gate: zero1 per-step gradient wire bytes (param
    # all_gather counted) at num_streams=8
    assert s["zero1_wire_ratio"] <= 0.55, s
    for row in doc["rows"]:
        assert row["wire_link_bytes"] > 0, row


@pytest.mark.slow
def test_overlap_schedule_smoke(tmp_path):
    """The overlap-scheduling sweep runs end-to-end and emits a well-formed
    BENCH json whose summary shows the tentpole claim: at 8 VCIs the
    overlap schedule strictly reduces MODELED exposed-comm time vs the post
    schedule for both optimizers, while moving identical wire bytes."""
    r = _run_bench(tmp_path, "benchmarks.overlap_schedule", "--devices", "8")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    path = tmp_path / "BENCH_overlap_schedule.json"
    assert path.is_file(), r.stdout
    doc = json.loads(path.read_text())
    cells = {(row["schedule"], row["num_vcis"], row["optimizer"])
             for row in doc["rows"]}
    assert ("post", 8, "replicated") in cells
    assert ("overlap", 8, "zero1") in cells
    measured = [row for row in doc["rows"] if row["ms_per_step"] is not None]
    assert measured, "no cell ran the real train step"
    for opt in ("replicated", "zero1"):
        s = doc["summary"][opt]
        assert s["exposed_ratio_8vcis"] < 1.0, (opt, s)
        assert s["wire_bytes_equal"], (opt, s)


@pytest.mark.slow
def test_trainer_streams_smoke(tmp_path):
    """The trainer-level stream sweep executes with the fast-path knobs."""
    r = _run_bench(tmp_path, "benchmarks.trainer_streams", "--devices", "8",
                   "--pack", "pallas", "--reduction", "reduce_scatter")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "trainer_vci_streams" in r.stdout
    assert "pallas" in r.stdout


@pytest.mark.slow
def test_trainer_streams_zero1_smoke(tmp_path):
    """The trainer-level sweep executes end-to-end with the ZeRO-1 sharded
    optimizer (scatter -> sharded AdamW -> param gather on VCI streams)."""
    r = _run_bench(tmp_path, "benchmarks.trainer_streams", "--devices", "8",
                   "--optimizer", "zero1", "--zero1-wire", "bfloat16")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "trainer_vci_streams" in r.stdout
    assert "zero1" in r.stdout


@pytest.mark.slow
def test_serve_streams_smoke(tmp_path):
    """The serve-path VCI-stream benchmark runs end-to-end and emits a
    well-formed BENCH json: a tok/s cell per (arch, batch, num_vcis), and a
    shallower collective critical path with a full pool than with 1 VCI."""
    r = _run_bench(tmp_path, "benchmarks.serve_streams", "--devices", "8")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    path = tmp_path / "BENCH_serve_streams.json"
    assert path.is_file(), r.stdout
    doc = json.loads(path.read_text())
    assert doc["mesh"]["tp"] > 1
    cells = {(row["arch"], row["num_vcis"]) for row in doc["rows"]}
    assert ("olmo-1b-smoke", 1) in cells
    assert ("mixtral-8x22b-smoke", 8) in cells
    for row in doc["rows"]:
        assert row["tok_s"] > 0 and row["collectives"] > 0, row
    for arch, s in doc["summary"].items():
        # the structural claim (transfers to TPU): dedicated streams shorten
        # the collective critical path vs the single fallback stream
        assert s["depth_maxvci"] < s["depth_1vci"], (arch, s)
        assert s["tok_s_1vci"] > 0 and s["tok_s_maxvci"] > 0
    # paged-vs-contiguous engine cells: both archs, both layouts, paged
    # admission under the mesh, and fewer resident cache bytes at equal
    # tokens (the paged acceptance claim)
    eng_cells = {(r["arch"], r["cache"]) for r in doc["engine_rows"]}
    for arch in ("olmo-1b-smoke", "mixtral-8x22b-smoke"):
        assert (arch, "paged") in eng_cells
        assert (arch, "contiguous") in eng_cells
    for r in doc["engine_rows"]:
        if r["cache"] == "paged":
            assert r["admit_under_mesh"], r
    for key, s in doc["engine_summary"].items():
        assert s["cache_bytes_ratio"] < 1.0, (key, s)
        assert s["tok_s_paged"] > 0 and s["tok_s_contiguous"] > 0


@pytest.mark.slow
def test_run_smoke_mode_single_benchmark(tmp_path):
    """The run.py --smoke driver executes a benchmark subprocess end-to-end."""
    env = multidev_env()
    env["BENCH_JSON_DIR"] = str(tmp_path)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke",
         "--only", "bucket_path", "--out", str(tmp_path / "bench")],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=1800)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "[ok] bucket_path" in r.stdout
    assert (tmp_path / "bench" / "bucket_path.csv").is_file()
