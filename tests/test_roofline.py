"""Roofline HLO-parser unit tests (synthetic HLO snippets) + term sanity."""

import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.roofline import (
    CollectiveOp,
    _shape_bytes,
    _split_computations,
    _while_trip_counts,
    analytic_flops,
    analytic_hbm_bytes,
    build_roofline,
    parse_collectives,
)


class TestShapeBytes:
    def test_simple(self):
        assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
        assert _shape_bytes("f32[4,4]") == 64
        assert _shape_bytes("s32[10]") == 40
        assert _shape_bytes("pred[8]") == 8

    def test_tuple(self):
        assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16

    def test_scalar_and_token(self):
        assert _shape_bytes("f32[]") == 4   # scalar: one element
        assert _shape_bytes("token[]") == 0


class TestLinkByteModel:
    def test_all_reduce_2x(self):
        op = CollectiveOp("all-reduce", 1000, group_size=8, computation="e")
        np.testing.assert_allclose(op.link_bytes, 1000 * 2 * 7 / 8)

    def test_all_gather_shard_times_n_minus_1(self):
        # operand is the per-device SHARD; ring AG ships it (n-1) times
        op = CollectiveOp("all-gather", 1000, group_size=4, computation="e")
        np.testing.assert_allclose(op.link_bytes, 3000)

    def test_permute_1x(self):
        op = CollectiveOp("collective-permute", 1000, group_size=16,
                          computation="e")
        np.testing.assert_allclose(op.link_bytes, 1000)

    def test_multiplier(self):
        op = CollectiveOp("all-to-all", 100, group_size=2, computation="e",
                          multiplier=5)
        np.testing.assert_allclose(op.link_bytes, 100 * 0.5 * 5)


SYNTHETIC_HLO = """\
HloModule test

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %w = (s32[], f32[64]) while(%t), condition=%cond, body=%body
  %ag = f32[256]{0} all-gather(f32[64]{0} %a), replica_groups={{0,1,2,3}}
}
"""


class TestHLOParse:
    def test_split_computations(self):
        comps = _split_computations(SYNTHETIC_HLO)
        assert {"body", "cond", "main"} <= set(comps)
        assert "all-reduce" in comps["body"]

    def test_trip_count_from_cond_constant(self):
        comps = _split_computations(SYNTHETIC_HLO)
        assert _while_trip_counts(comps) == {"body": 12}

    def test_trip_count_prefers_backend_config(self):
        hlo = SYNTHETIC_HLO.replace(
            "body=%body",
            'body=%body, backend_config={"known_trip_count":{"n":"7"}}')
        comps = _split_computations(hlo)
        assert _while_trip_counts(comps) == {"body": 7}

    def test_collectives_multiplied_by_trip_count(self):
        ops = parse_collectives(SYNTHETIC_HLO, 4)
        by_kind = {o.kind: o for o in ops}
        ar = by_kind["all-reduce"]
        assert ar.multiplier == 12
        assert ar.group_size == 4
        # payload from operand f32[64]... operand sig is "%x" -> falls back to out
        assert ar.bytes_payload == 64 * 4
        ag = by_kind["all-gather"]
        assert ag.multiplier == 1
        assert ag.bytes_payload == 64 * 4  # operand, not the bigger output

    def test_iota_replica_groups(self):
        hlo = SYNTHETIC_HLO.replace("replica_groups={{0,1,2,3}}",
                                    "replica_groups=[2,8]<=[16]")
        ops = parse_collectives(hlo, 16)
        assert all(o.group_size == 8 for o in ops)


class TestAnalyticTerms:
    @pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x22b",
                                      "mamba2-780m"])
    def test_train_flops_dominated_by_6nd(self, arch):
        cfg = get_config(arch)
        shape = INPUT_SHAPES["train_4k"]
        fl = analytic_flops(cfg, shape)
        model = 6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
        np.testing.assert_allclose(fl["model"], model)
        assert fl["total"] >= fl["model"]
        assert fl["total"] < 3.0 * fl["model"]  # remat+attn bounded

    def test_decode_flops_2n(self):
        cfg = get_config("olmo-1b")
        shape = INPUT_SHAPES["decode_32k"]
        fl = analytic_flops(cfg, shape)
        np.testing.assert_allclose(
            fl["model"], 2.0 * cfg.active_param_count() * shape.global_batch)

    def test_decode_memory_weights_plus_kv(self):
        cfg = get_config("command-r-35b")
        shape = INPUT_SHAPES["decode_32k"]
        got = analytic_hbm_bytes(cfg, shape)
        w = cfg.param_count() * 2
        kv = (shape.global_batch * shape.seq_len * cfg.kv_dim * 2 * 2
              * cfg.num_layers)
        assert got >= w + kv          # both terms present
        assert got < 1.5 * (w + kv)   # nothing spurious dominates

    def test_roofline_report_fields(self):
        cfg = get_config("olmo-1b")
        shape = INPUT_SHAPES["train_4k"]
        rl = build_roofline(cfg, shape, "16x16", 256, SYNTHETIC_HLO,
                            {"flops": 1e12}, None)
        row = rl.row()
        for k in ("t_compute_s", "t_memory_s", "t_collective_s", "dominant",
                  "model_ratio", "collectives"):
            assert k in row
        assert row["dominant"] in ("compute", "memory", "collective")
        assert 0 < row["model_ratio"] <= 1.0
