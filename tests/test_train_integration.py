"""End-to-end training integration tests (single device, tiny models)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.optim.schedule import cosine_schedule
from repro.train.trainer import make_train_step, train_state_init
from repro.checkpoint.io import load_checkpoint, save_checkpoint


@pytest.mark.slow
def test_loss_decreases_on_learnable_data():
    """The successor process is learnable: 40 steps must cut CE well below
    the uniform baseline trajectory."""
    cfg = get_config("olmo-1b-smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    lr_fn = lambda s: cosine_schedule(s, peak=3e-3, warmup_steps=5,
                                      total_steps=40)
    step = jax.jit(make_train_step(cfg, lr_fn=lr_fn))
    first = None
    for i in range(40):
        batch = synthetic_batch(cfg, 8, 64, seed=0, step=i)
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["ce"])
        last = float(metrics["ce"])
    assert np.isfinite(last)
    assert last < 0.7 * first, (first, last)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 over the same data == one step over the full batch."""
    cfg = get_config("olmo-1b-smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 4, 32, seed=0)

    s1, m1 = jax.jit(make_train_step(cfg, accum_steps=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, accum_steps=2))(state, batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-4)
    # atol sits above the worst near-zero element seen under CI's
    # 8-virtual-device XLA_FLAGS (different CPU reduction fusion than the
    # 1-device compile; AdamW's 1/sqrt(v) amplifies the tiny-grad tail to
    # ~1e-4 on ~1e-4-magnitude params, where rtol alone is meaningless).
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_checkpoint_resume_bitexact(tmp_path):
    """save -> 2 steps -> vs -> save/load -> 2 steps must agree."""
    cfg = get_config("olmo-1b-smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    b0 = synthetic_batch(cfg, 2, 32, seed=0, step=0)
    b1 = synthetic_batch(cfg, 2, 32, seed=0, step=1)

    state, _ = step(state, b0)
    save_checkpoint(str(tmp_path), 1, state)
    cont, m_direct = step(state, b1)

    restored = load_checkpoint(str(tmp_path), 1, state)
    resumed, m_resumed = step(restored, b1)
    np.testing.assert_allclose(float(m_direct["loss"]),
                               float(m_resumed["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(cont.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_remat_matches_no_remat():
    """Activation checkpointing must not change the math."""
    from dataclasses import replace
    base = get_config("yi-9b-smoke")
    batch = synthetic_batch(base, 2, 32, seed=0)
    outs = {}
    for remat in ("none", "block"):
        cfg = replace(base, remat=remat)
        state = train_state_init(cfg, jax.random.PRNGKey(0))
        _, metrics = jax.jit(make_train_step(cfg))(state, batch)
        outs[remat] = float(metrics["loss"])
    np.testing.assert_allclose(outs["none"], outs["block"], rtol=1e-5)


@pytest.mark.slow
def test_moe_training_is_stable():
    """MoE with aux losses: 20 steps, no NaN, load-balance near 1."""
    cfg = get_config("mixtral-8x22b-smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, lr_fn=lambda s: 1e-3))
    for i in range(20):
        batch = synthetic_batch(cfg, 4, 32, seed=0, step=i)
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"])), i
    lb = float(metrics["load_balance"])
    assert 0.9 < lb < 4.0, lb
