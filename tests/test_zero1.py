"""ZeRO-1 sharded-optimizer conformance suite.

Headline test: ``check_zero1_matches_replicated`` (tests/_multidev_checks.py)
runs 5 steps of ``make_train_step(optimizer="zero1")`` against the replicated
path on the 8-device CPU mesh for a dense AND an MoE config, asserting
params/metrics agree to fp32 tolerance — proving the reduce_scatter-shard ->
sharded-AdamW -> param-all_gather cycle is numerically equivalent to full
DDP while moving half the gradient bytes.

The single-device tests below pin the flat-bucket-space optimizer math
itself (decay masks, global-norm clip, moment updates) against the per-leaf
reference implementation, with no mesh in the loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bucketing import ShardLayout, plan_buckets, unpack_bucket
from repro.optim.adamw import (adamw_init, adamw_update, bucket_decay_masks,
                               sharded_adamw_init, sharded_adamw_update)


def _param_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(16, 32)), jnp.float32),
        "blk": {"wo": jnp.asarray(rng.normal(size=(8, 8, 4)), jnp.float32),
                "scale": jnp.asarray(rng.normal(size=(129,)), jnp.float32)},
        "bias": jnp.asarray(rng.normal(size=(3,)), jnp.float32),
    }


def _grad_tree(seed=1):
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(
            np.random.default_rng(seed + p.size).normal(size=p.shape) * 0.1,
            jnp.float32), _param_tree())


@pytest.mark.parametrize("max_grad_norm", [1.0, 0.05, None])
def test_sharded_adamw_matches_replicated_math(max_grad_norm):
    """axis_size=1 sharded AdamW == per-leaf adamw_update, for 3 steps.

    With one rank the shard IS the whole bucket, so any disagreement is a
    flat-space math bug (mask, clip, bias correction), not a comm bug.
    """
    params = _param_tree()
    plan = plan_buckets(params, 2, align=8)
    layout = ShardLayout(plan, 1)
    masks = bucket_decay_masks(plan)

    ref_state = adamw_init(params)
    z_state = sharded_adamw_init(params, plan)
    ref_params = params
    for step in range(3):
        grads = _grad_tree(seed=step)
        ref_params, ref_state, ref_metrics = adamw_update(
            grads, ref_state, ref_params, lr=jnp.float32(1e-2),
            max_grad_norm=max_grad_norm)

        leaves = jax.tree_util.tree_leaves(grads)
        flat = [jnp.zeros((b.padded_size,), jnp.float32) for b in plan.buckets]
        for bi, b in enumerate(plan.buckets):
            for s in b.slots:
                flat[bi] = jax.lax.dynamic_update_slice(
                    flat[bi], leaves[s.index].reshape(-1), (s.offset,))
        # axis_size=1: the full-bucket masks ARE the rank-0 shard masks
        shards, z_state, z_metrics = sharded_adamw_update(
            flat, z_state, lr=jnp.float32(1e-2), layout=layout,
            decay_masks=masks, max_grad_norm=max_grad_norm)
        np.testing.assert_allclose(float(z_metrics["grad_norm"]),
                                   float(ref_metrics["grad_norm"]), rtol=1e-6)

        got = [None] * len(leaves)
        for shard, b in zip(shards, plan.buckets):
            for idx, val in unpack_bucket(shard, b):
                got[idx] = val
        for g, e in zip(got, jax.tree_util.tree_leaves(ref_params)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                       rtol=1e-6, atol=1e-7)


def test_decay_mask_marks_matrices_only():
    params = _param_tree()
    plan = plan_buckets(params, 2, align=8)
    masks = bucket_decay_masks(plan)
    leaves = jax.tree_util.tree_leaves(params)
    for b, mask in zip(plan.buckets, masks):
        covered = np.zeros(b.padded_size, bool)
        for s in b.slots:
            want = 1.0 if len(s.shape) >= 2 else 0.0
            seg = mask[s.offset:s.offset + s.size]
            assert (seg == want).all(), (s, want)
            assert leaves[s.index].ndim == len(s.shape)
            covered[s.offset:s.offset + s.size] = True
        # padding (incl. inter-slot gaps) never decays
        assert (mask[~covered] == 0.0).all()


def test_sharded_state_is_one_over_n():
    """The 1/N memory claim: per-rank shard elements * N == total padded."""
    params = _param_tree()
    plan = plan_buckets(params, 3, align=16)
    for n in (1, 2, 4, 8):
        layout = ShardLayout(plan, n)
        assert layout.total_shard_elems * n == plan.total_padded


def test_shard_layout_rejects_indivisible():
    params = {"a": jnp.zeros((10,))}
    plan = plan_buckets(params, 1, align=5)  # padded_size 10
    with pytest.raises(ValueError):
        ShardLayout(plan, 4)


def test_state_init_requires_matching_tree():
    params = _param_tree()
    plan = plan_buckets(params, 2, align=8)
    with pytest.raises(ValueError):
        sharded_adamw_init({"other": jnp.zeros((4,))}, plan)


# ---------------------------------------------------------------------------
# 8-device conformance (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.multidev
def test_zero1_matches_replicated(multidev):
    """5 steps zero1 vs replicated, dense + MoE configs, fp32 tolerance."""
    r = multidev("_multidev_checks.py", "zero1_matches_replicated")
    assert r.returncode == 0, \
        f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "PASS zero1_matches_replicated" in r.stdout
