"""Sharding-rule tests: spec trees must cover the param trees exactly and
respect divisibility, for every assigned arch on both production meshes.

Uses a FAKE mesh object (duck-typed: .axis_names + .shape) so the main
pytest process never touches jax device state — the actual lower/compile of
every combination is exercised by launch/dryrun.py (reports/dryrun/).
"""

from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import batch_axes, param_specs
from repro.models.transformer import init_params


def fake_mesh(shape_dict):
    return SimpleNamespace(axis_names=tuple(shape_dict),
                           shape=dict(shape_dict),
                           size=int(__import__("numpy").prod(
                               list(shape_dict.values()))))


MESHES = {
    "16x16": {"data": 16, "model": 16},
    "2x16x16": {"pod": 2, "data": 16, "model": 16},
}


def _spec_leaves(specs):
    return jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_match_param_tree(arch, mesh_name):
    full = get_config(arch)
    mesh = fake_mesh(MESHES[mesh_name])
    params = jax.eval_shape(lambda k: init_params(full, k),
                            jax.ShapeDtypeStruct((2,), "uint32"))
    specs = param_specs(full, mesh)
    sd = jax.tree_util.tree_structure(params)
    ss = jax.tree_util.tree_structure(specs,
                                      is_leaf=lambda x: isinstance(x, P))
    assert sd == ss, f"{arch} spec tree != param tree"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_rank_and_divisibility(arch):
    """Every spec dim must divide its tensor dim on the production mesh."""
    full = get_config(arch)
    mesh = fake_mesh(MESHES["2x16x16"])
    params = jax.eval_shape(lambda k: init_params(full, k),
                            jax.ShapeDtypeStruct((2,), "uint32"))
    specs = param_specs(full, mesh)
    leaves = jax.tree_util.tree_leaves(params)
    spec_leaves = _spec_leaves(specs)
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, f"{arch}: dim {dim} % {axes} ({n}) != 0"


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "arctic-480b"])
def test_expert_weights_expert_parallel(arch):
    """MoE expert weight tables shard experts over data axes when E divides."""
    full = get_config(arch)
    mesh = fake_mesh(MESHES["16x16"])
    specs = param_specs(full, mesh)
    wg = specs["layers"]["moe"]["w_gate"]
    E = full.moe.num_experts
    if E % 16 == 0:  # arctic: 128 % 16 == 0 -> expert parallel
        ax = tuple(wg)[1]
        axes = ax if isinstance(ax, tuple) else (ax,)
        assert "data" in axes
    else:            # mixtral: 8 experts -> FSDP fallback on d_model
        assert tuple(wg)[1] is None


def test_batch_axes():
    assert batch_axes(fake_mesh(MESHES["16x16"])) == ("data",)
    assert batch_axes(fake_mesh(MESHES["2x16x16"])) == ("pod", "data")
    assert batch_axes(None) == ("data",)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_tp_spec_targets_model_axis(arch):
    """At least the big matmuls must be TP-sharded over 'model'."""
    full = get_config(arch)
    mesh = fake_mesh(MESHES["16x16"])
    specs = param_specs(full, mesh)
    flat = _spec_leaves(specs)
    uses_model = any("model" in [a for ax in tuple(s) if ax is not None
                                 for a in (ax if isinstance(ax, tuple) else (ax,))]
                     for s in flat)
    assert uses_model, f"{arch} has no tensor parallelism at all"
