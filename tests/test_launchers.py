"""CLI driver smoke tests (subprocess; single real device)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(mod, *args, timeout=900, devices=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run([sys.executable, "-m", mod, *args],
                          capture_output=True, text=True, cwd=REPO,
                          env=env, timeout=timeout)


def test_train_cli(tmp_path):
    r = run_cli("repro.launch.train", "--arch", "olmo-1b-smoke",
                "--steps", "12", "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "6",
                "--log-every", "6")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step    12" in r.stdout
    assert (tmp_path / "step_00000012").is_dir()
    # resume path
    r2 = run_cli("repro.launch.train", "--arch", "olmo-1b-smoke",
                 "--steps", "14", "--batch", "2", "--seq", "32",
                 "--ckpt-dir", str(tmp_path), "--log-every", "2")
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 12" in r2.stdout


def test_train_cli_vci_mode():
    r = run_cli("repro.launch.train", "--arch", "olmo-1b-smoke",
                "--steps", "4", "--batch", "4", "--seq", "32",
                "--mesh", "4", "--comm", "vci", "--num-streams", "4",
                "--progress", "hybrid", "--log-every", "2", devices=4)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step     4" in r.stdout


def test_serve_cli():
    r = run_cli("repro.launch.serve", "--arch", "mamba2-780m-smoke",
                "--requests", "2", "--batch", "2", "--prompt-len", "8",
                "--max-new", "4", "--max-len", "32")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


@pytest.mark.slow
def test_dryrun_cli_single_pair():
    r = run_cli("repro.launch.dryrun", "--arch", "olmo-1b",
                "--shape", "decode_32k", "--out", "/tmp/dryrun_test_out",
                timeout=1200)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[ok] olmo-1b__decode_32k__16x16" in r.stdout


def test_report_cli():
    if not os.path.isdir(os.path.join(REPO, "reports", "dryrun_baseline")):
        pytest.skip("reports/dryrun_baseline artifact not present in checkout "
                    "(produced by a full launch/dryrun sweep)")
    r = run_cli("repro.launch.report", "--dir", "reports/dryrun_baseline",
                "--mesh", "16x16")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "80 ok / 0 failed" in r.stdout
    assert "| arch | shape |" in r.stdout
