"""Hypothesis property tests on system invariants.

hypothesis is an optional dep: the @given tests are defined only when it
imports, so tier-1 collection never hard-fails on the missing package; the
example-based tests below run either way.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dep absent in minimal envs
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy-construction call at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

from repro.core.bucketing import ShardLayout, pack_bucket, plan_buckets, \
    unpack_bucket
from repro.core.vci import VCIPool
from repro.models.layers import apply_rope, layer_norm, rms_norm
from repro.models.attention import causal_mask


# ---------------------------------------------------------------------------
# VCI pool invariants under arbitrary acquire/release interleavings
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(
    num_vcis=st.integers(1, 8),
    policy=st.sampled_from(["fcfs", "round_robin", "hash", "hinted"]),
    ops=st.lists(st.tuples(st.booleans(), st.integers(0, 15)), max_size=40),
)
def test_vci_pool_invariants(num_vcis, policy, ops):
    pool = VCIPool(num_vcis=num_vcis, policy=policy)
    held = {}
    for acquire, key in ops:
        name = f"ctx{key}"
        if acquire and name not in held:
            v = pool.acquire(name)
            held[name] = v.index
            # I1: indices always in range
            assert 0 <= v.index < num_vcis
        elif not acquire and name in held:
            pool.release(name)
            del held[name]
    # I2: the pool tracks exactly the held contexts
    assert pool.active == len(held)
    # I3 (fcfs): a non-fallback VCI is held by at most one context
    if policy == "fcfs":
        non_fb = [v for v in held.values() if v != VCIPool.FALLBACK]
        assert len(non_fb) == len(set(non_fb))


# ---------------------------------------------------------------------------
# numeric layer invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3), s=st.integers(1, 8),
    hd=st.sampled_from([2, 4, 8, 64]),
    scale=st.floats(0.1, 100.0),
)
def test_rope_preserves_norms(b, s, hd, scale):
    """RoPE is a rotation: per-pair L2 norms are invariant."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, s, 2, hd)) * scale, jnp.float32)
    pos = jnp.arange(s)
    y = apply_rope(x, pos, 10_000.0)
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=2e-4)


def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on m - n."""
    hd = 32
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    def dot(m, n):
        qm = apply_rope(q, jnp.array([m]), 10_000.0)
        kn = apply_rope(k, jnp.array([n]), 10_000.0)
        return float(jnp.sum(qm * kn))

    np.testing.assert_allclose(dot(5, 3), dot(12, 10), rtol=1e-4)
    np.testing.assert_allclose(dot(7, 7), dot(0, 0), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(0.1, 1e3))  # below ~0.1 the eps=1e-6 floor kicks in
def test_rms_norm_scale_invariant(scale):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    a = rms_norm(x)
    b = rms_norm(x * scale)
    # eps=1e-6 inside the rsqrt gives a small scale-dependent shift
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-4)


def test_nonparametric_layer_norm_output_stats():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 256)) * 10 + 3, jnp.float32)
    y = np.asarray(layer_norm(x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


@settings(max_examples=30, deadline=None)
@given(q=st.integers(1, 12), kv=st.integers(1, 12),
       w=st.one_of(st.none(), st.integers(1, 12)),
       off=st.integers(0, 8))
def test_causal_mask_properties(q, kv, w, off):
    m = np.asarray(causal_mask(q, kv, window=w, q_offset=off))
    assert m.shape == (q, kv)
    for i in range(q):
        for j in range(kv):
            expect = j <= i + off
            if w is not None:
                expect = expect and j > i + off - w
            assert m[i, j] == expect


# ---------------------------------------------------------------------------
# ShardLayout (ZeRO-1 ownership map) invariants
# ---------------------------------------------------------------------------

def _random_shapes(rng, max_leaves=10):
    n_leaves = int(rng.integers(1, max_leaves + 1))
    shapes = []
    for _ in range(n_leaves):
        nd = int(rng.integers(0, 4))
        shapes.append(tuple(int(rng.integers(1, 20)) for _ in range(nd)))
    return shapes


def _check_layout_invariants(shapes, num_streams, axis_size, align):
    """The three ShardLayout invariants for one (tree, knobs) draw:
    shard bounds tile each padded bucket exactly, every LeafSlot element
    has exactly one owner, and slot_owners returns a clean partition."""
    tree = {f"l{i}": jax.ShapeDtypeStruct(s, jnp.float32)
            for i, s in enumerate(shapes)}
    plan = plan_buckets(tree, num_streams, align=align)
    layout = ShardLayout(plan, axis_size)
    assert layout.total_shard_elems * axis_size == plan.total_padded
    for bid, b in enumerate(plan.buckets):
        bounds = layout.shard_bounds(bid)
        # tiling: starts at 0, contiguous, ends at padded_size, equal sizes
        assert bounds[0][0] == 0 and bounds[-1][1] == b.padded_size
        assert all(bounds[r][1] == bounds[r + 1][0]
                   for r in range(len(bounds) - 1))
        assert len({hi - lo for lo, hi in bounds}) == 1
        for s in b.slots:
            pieces = layout.slot_owners(bid, s)
            # pieces partition [offset, offset+size) with increasing ranks
            assert pieces[0][1] == s.offset
            assert pieces[-1][2] == s.offset + s.size
            assert all(p[2] == q[1] for p, q in zip(pieces, pieces[1:]))
            assert [p[0] for p in pieces] == sorted({p[0] for p in pieces})
            # ...and owner_of agrees element-wise: exactly one owner each
            for rank, lo, hi in pieces:
                for off in (lo, hi - 1):
                    assert layout.owner_of(bid, off) == rank
    return plan, layout


def test_shard_layout_invariants_examples():
    """Deterministic sweep of the ShardLayout invariants (runs with or
    without hypothesis)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        axis_size = int(2 ** rng.integers(0, 4))
        align = axis_size * int(rng.integers(1, 9))
        _check_layout_invariants(_random_shapes(rng),
                                 int(rng.integers(1, 7)), axis_size, align)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), num_streams=st.integers(1, 8),
       axis_pow=st.integers(0, 3), align_mult=st.integers(1, 16))
def test_shard_layout_invariants(seed, num_streams, axis_pow, align_mult):
    rng = np.random.default_rng(seed)
    axis_size = 2 ** axis_pow
    _check_layout_invariants(_random_shapes(rng), num_streams, axis_size,
                             axis_size * align_mult)


def _check_zero1_roundtrip(shapes, num_streams, axis_size, align, seed):
    """pack -> scatter -> zero local update -> all_gather -> unpack == id.

    The scatter/gather are simulated by slicing/concatenating the flat
    buffer (what psum_scatter/all_gather do to a replicated operand), so the
    identity isolates the LAYOUT math: any offset/shard-boundary bug
    scrambles leaves.
    """
    from repro.optim.adamw import bucket_decay_masks, sharded_adamw_init, \
        sharded_adamw_update

    from repro.optim.adamw import ShardedAdamWState

    rng = np.random.default_rng(seed)
    tree = {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}
    plan = plan_buckets(tree, num_streams, align=align)
    layout = ShardLayout(plan, axis_size)
    masks = bucket_decay_masks(plan)
    state = sharded_adamw_init(tree, plan)
    leaves = jax.tree_util.tree_leaves(tree)
    packed = [pack_bucket(leaves, b) for b in plan.buckets]

    # every rank runs the real sharded update (lr=0 => zero update) on its
    # simulated scatter output; per-bucket gather = concat over ranks
    per_rank = []
    for rank in range(axis_size):
        bounds = [layout.shard_bounds(bid)[rank]
                  for bid in range(plan.num_buckets)]
        local = ShardedAdamWState(
            m=tuple(state.m[b][lo:hi] for b, (lo, hi) in enumerate(bounds)),
            v=tuple(state.v[b][lo:hi] for b, (lo, hi) in enumerate(bounds)),
            master=tuple(state.master[b][lo:hi]
                         for b, (lo, hi) in enumerate(bounds)),
            count=state.count)
        shards, _, _ = sharded_adamw_update(
            [p[lo:hi] for p, (lo, hi) in zip(packed, bounds)], local,
            lr=jnp.float32(0.0), layout=layout,
            decay_masks=[m[lo:hi] for m, (lo, hi) in zip(masks, bounds)],
            max_grad_norm=1.0)
        assert all(s.shape == (layout.shard_sizes[b],)
                   for b, s in enumerate(shards))
        per_rank.append(shards)
    gathered = [jnp.concatenate([per_rank[r][bid] for r in range(axis_size)])
                for bid in range(plan.num_buckets)]

    got = [None] * len(leaves)
    for flat, b in zip(gathered, plan.buckets):
        for idx, val in unpack_bucket(flat, b):
            got[idx] = val
    for g, e in zip(got, leaves):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def test_zero1_roundtrip_identity_examples():
    for seed in range(6):
        rng = np.random.default_rng(100 + seed)
        axis_size = int(2 ** rng.integers(0, 4))
        align = axis_size * int(rng.integers(1, 9))
        _check_zero1_roundtrip(_random_shapes(rng, max_leaves=6),
                               int(rng.integers(1, 5)), axis_size, align,
                               seed)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), num_streams=st.integers(1, 5),
       axis_pow=st.integers(0, 3), align_mult=st.integers(1, 8))
def test_zero1_roundtrip_identity(seed, num_streams, axis_pow, align_mult):
    axis_size = 2 ** axis_pow
    rng = np.random.default_rng(seed)
    _check_zero1_roundtrip(_random_shapes(rng, max_leaves=6), num_streams,
                           axis_size, axis_size * align_mult, seed)


# ---------------------------------------------------------------------------
# MoE router invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_moe_dropfree_is_exact_topk_mixture(seed):
    """With capacity >= S*K the dispatch must equal the explicit per-token
    top-k mixture of expert FFNs — no drops, no misrouting."""
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.moe import moe_ffn
    from repro.models.layers import gated_ffn
    from repro.models.transformer import init_params

    cfg = get_config("mixtral-8x22b-smoke")
    cfg = replace(cfg, moe=replace(cfg.moe,
                                   capacity_factor=float(cfg.moe.num_experts),
                                   capacity_factor_eval=float(cfg.moe.num_experts)))
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, cfg.d_model),
                          jnp.float32)
    y, aux = moe_ffn(cfg, x, lp, None, inference=True)

    logits = (x @ lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    expect = jnp.zeros_like(x)
    for e in range(cfg.moe.num_experts):
        pe = {k: lp[k][e] for k in ("w_gate", "w_up", "w_down")}
        ye = gated_ffn(cfg, x, pe)
        wsel = ((eidx == e) * gates).sum(-1)[..., None]
        expect = expect + ye * wsel
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=5e-4, atol=5e-5)
    # Switch LB loss ~>= 1 (soft probs vs hard counts allow a small dip)
    assert float(aux["load_balance"]) >= 0.98


# ---------------------------------------------------------------------------
# Page allocator (paged KV cache ownership map) invariants
# ---------------------------------------------------------------------------

def _page_alloc_driver(seed, num_pages, batch, max_pages, n_ops,
                       use_jit=False):
    """Random alloc/step-alloc/free interleaving; checks after EVERY op:

    I1 no double ownership — each mapped table entry names an in-range,
       non-trash pool page whose owner IS that slot, and no pool page is
       mapped by two entries;
    I2 owner/table agree — a slot owns exactly the pages its row maps;
    I3 failed allocs stay consistent — ``ok`` is False iff the pool had
       fewer free pages than requested, and partial results still satisfy
       I1/I2. Returns the final state (for the reclamation/jit checks).
    """
    from repro.serve import paging as pg

    alloc = pg.alloc_slot_pages_jit if use_jit else pg.alloc_slot_pages
    step = pg.alloc_step_pages_jit if use_jit else pg.alloc_step_pages
    free = pg.free_slot_pages_jit if use_jit else pg.free_slot_pages

    rng = np.random.default_rng(seed)
    st = pg.page_state_init(num_pages, batch, max_pages)
    mapped = {b: set() for b in range(batch)}  # slot -> mapped logicals

    def check(st):
        table = np.asarray(st.table)
        owner = np.asarray(st.owner)
        assert owner[pg.TRASH_PAGE] == pg.OWNER_RESERVED
        seen = {}
        for b in range(batch):
            ids = table[b][table[b] >= 0]
            for pid in ids:
                assert pg.TRASH_PAGE < pid < num_pages, (b, pid)
                assert owner[pid] == b, (b, pid, owner[pid])
                assert pid not in seen, f"page {pid} mapped twice"
                seen[pid] = b
        # I2: ownership without a table entry would leak a page
        for pid in range(num_pages):
            if owner[pid] >= 0:
                assert pid in seen and seen[pid] == owner[pid]

    for _ in range(n_ops):
        op = rng.integers(0, 3)
        free_now = int(np.asarray(pg.pages_free(st)))
        if op == 0:  # range alloc for one slot
            b = int(rng.integers(0, batch))
            avail = sorted(set(range(max_pages)) - mapped[b])
            if not avail:
                continue
            n = int(rng.integers(1, len(avail) + 1))
            logical = jnp.asarray(avail[:n], jnp.int32)
            st, ok = alloc(st, jnp.asarray(b, jnp.int32), logical)
            assert bool(ok) == (free_now >= n)
            got = np.asarray(st.table)[b, np.asarray(logical)]
            mapped[b] |= {int(p) for p, g in zip(avail[:n], got) if g >= 0}
        elif op == 1:  # decode page-boundary alloc
            log = int(rng.integers(0, max_pages))
            slots = [b for b in range(batch) if log not in mapped[b]]
            if not slots:
                continue
            st, ok = step(st, jnp.asarray(slots, jnp.int32),
                          jnp.asarray(log, jnp.int32))
            assert bool(ok) == (free_now >= len(slots))
            got = np.asarray(st.table)[np.asarray(slots), log]
            for b, g in zip(slots, got):
                if g >= 0:
                    mapped[b].add(log)
        else:  # free a slot
            b = int(rng.integers(0, batch))
            st = free(st, jnp.asarray(b, jnp.int32))
            mapped[b] = set()
        check(st)

    # full reclamation: freeing every slot returns the whole pool
    for b in range(batch):
        st = free(st, jnp.asarray(b, jnp.int32))
    owner = np.asarray(st.owner)
    assert int(np.asarray(pg.pages_used(st))) == 0
    assert (np.asarray(st.table) == -1).all()
    assert (owner[1:] == pg.OWNER_FREE).all()
    return st


def test_page_alloc_invariants_examples():
    """Deterministic sweep (runs with or without hypothesis)."""
    for seed in range(6):
        rng = np.random.default_rng(200 + seed)
        _page_alloc_driver(seed,
                           num_pages=int(rng.integers(2, 20)),
                           batch=int(rng.integers(1, 6)),
                           max_pages=int(rng.integers(1, 8)),
                           n_ops=20)


def test_page_alloc_roundtrips_through_jit():
    """The jitted allocator ops produce bit-identical state to the eager
    ones over a shared op sequence (the engine calls the jitted forms)."""
    for seed in (0, 3):
        a = _page_alloc_driver(seed, num_pages=12, batch=3, max_pages=5,
                               n_ops=15, use_jit=False)
        b = _page_alloc_driver(seed, num_pages=12, batch=3, max_pages=5,
                               n_ops=15, use_jit=True)
        np.testing.assert_array_equal(np.asarray(a.table), np.asarray(b.table))
        np.testing.assert_array_equal(np.asarray(a.owner), np.asarray(b.owner))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), num_pages=st.integers(2, 24),
       batch=st.integers(1, 6), max_pages=st.integers(1, 8),
       n_ops=st.integers(1, 25))
def test_page_alloc_invariants(seed, num_pages, batch, max_pages, n_ops):
    _page_alloc_driver(seed, num_pages=num_pages, batch=batch,
                       max_pages=max_pages, n_ops=n_ops)
