"""Hypothesis property tests on system invariants.

hypothesis is an optional dep: the @given tests are defined only when it
imports, so tier-1 collection never hard-fails on the missing package; the
example-based tests below run either way.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dep absent in minimal envs
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy-construction call at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

from repro.core.vci import VCIPool
from repro.models.layers import apply_rope, layer_norm, rms_norm
from repro.models.attention import causal_mask


# ---------------------------------------------------------------------------
# VCI pool invariants under arbitrary acquire/release interleavings
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(
    num_vcis=st.integers(1, 8),
    policy=st.sampled_from(["fcfs", "round_robin", "hash", "hinted"]),
    ops=st.lists(st.tuples(st.booleans(), st.integers(0, 15)), max_size=40),
)
def test_vci_pool_invariants(num_vcis, policy, ops):
    pool = VCIPool(num_vcis=num_vcis, policy=policy)
    held = {}
    for acquire, key in ops:
        name = f"ctx{key}"
        if acquire and name not in held:
            v = pool.acquire(name)
            held[name] = v.index
            # I1: indices always in range
            assert 0 <= v.index < num_vcis
        elif not acquire and name in held:
            pool.release(name)
            del held[name]
    # I2: the pool tracks exactly the held contexts
    assert pool.active == len(held)
    # I3 (fcfs): a non-fallback VCI is held by at most one context
    if policy == "fcfs":
        non_fb = [v for v in held.values() if v != VCIPool.FALLBACK]
        assert len(non_fb) == len(set(non_fb))


# ---------------------------------------------------------------------------
# numeric layer invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3), s=st.integers(1, 8),
    hd=st.sampled_from([2, 4, 8, 64]),
    scale=st.floats(0.1, 100.0),
)
def test_rope_preserves_norms(b, s, hd, scale):
    """RoPE is a rotation: per-pair L2 norms are invariant."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, s, 2, hd)) * scale, jnp.float32)
    pos = jnp.arange(s)
    y = apply_rope(x, pos, 10_000.0)
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=2e-4)


def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on m - n."""
    hd = 32
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    def dot(m, n):
        qm = apply_rope(q, jnp.array([m]), 10_000.0)
        kn = apply_rope(k, jnp.array([n]), 10_000.0)
        return float(jnp.sum(qm * kn))

    np.testing.assert_allclose(dot(5, 3), dot(12, 10), rtol=1e-4)
    np.testing.assert_allclose(dot(7, 7), dot(0, 0), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(0.1, 1e3))  # below ~0.1 the eps=1e-6 floor kicks in
def test_rms_norm_scale_invariant(scale):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    a = rms_norm(x)
    b = rms_norm(x * scale)
    # eps=1e-6 inside the rsqrt gives a small scale-dependent shift
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-4)


def test_nonparametric_layer_norm_output_stats():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 256)) * 10 + 3, jnp.float32)
    y = np.asarray(layer_norm(x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


@settings(max_examples=30, deadline=None)
@given(q=st.integers(1, 12), kv=st.integers(1, 12),
       w=st.one_of(st.none(), st.integers(1, 12)),
       off=st.integers(0, 8))
def test_causal_mask_properties(q, kv, w, off):
    m = np.asarray(causal_mask(q, kv, window=w, q_offset=off))
    assert m.shape == (q, kv)
    for i in range(q):
        for j in range(kv):
            expect = j <= i + off
            if w is not None:
                expect = expect and j > i + off - w
            assert m[i, j] == expect


# ---------------------------------------------------------------------------
# MoE router invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_moe_dropfree_is_exact_topk_mixture(seed):
    """With capacity >= S*K the dispatch must equal the explicit per-token
    top-k mixture of expert FFNs — no drops, no misrouting."""
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.moe import moe_ffn
    from repro.models.layers import gated_ffn
    from repro.models.transformer import init_params

    cfg = get_config("mixtral-8x22b-smoke")
    cfg = replace(cfg, moe=replace(cfg.moe,
                                   capacity_factor=float(cfg.moe.num_experts),
                                   capacity_factor_eval=float(cfg.moe.num_experts)))
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, cfg.d_model),
                          jnp.float32)
    y, aux = moe_ffn(cfg, x, lp, None, inference=True)

    logits = (x @ lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    expect = jnp.zeros_like(x)
    for e in range(cfg.moe.num_experts):
        pe = {k: lp[k][e] for k in ("w_gate", "w_up", "w_down")}
        ye = gated_ffn(cfg, x, pe)
        wsel = ((eidx == e) * gates).sum(-1)[..., None]
        expect = expect + ye * wsel
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=5e-4, atol=5e-5)
    # Switch LB loss ~>= 1 (soft probs vs hard counts allow a small dip)
    assert float(aux["load_balance"]) >= 0.98
