"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU).

Every kernel in repro.kernels is swept over shapes and dtypes and asserted
allclose against its ref.py oracle, per the assignment.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import flash_attention, row_gather, ssd_chunked
from repro.kernels.moe_gather import row_gather_ref
from repro.models import ssm as ssm_mod

jax.config.update("jax_enable_x64", False)


def _qkv(key, b, h, kv, sq, sk, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, sk, hd), jnp.float32).astype(dtype)
    return q, k, v


_TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("sq,sk", [(128, 128), (256, 128), (128, 384),
                                       (96, 160), (64, 64)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_shapes_causal(self, sq, sk, causal):
        q, k, v = _qkv(jax.random.PRNGKey(0), 2, 4, 4, sq, sk, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
        expect = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, expect, **_TOL[jnp.float32])

    @pytest.mark.parametrize("h,kv", [(8, 8), (8, 2), (8, 1)])
    def test_gqa_mqa(self, h, kv):
        q, k, v = _qkv(jax.random.PRNGKey(1), 1, h, kv, 128, 128, 64,
                       jnp.float32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, expect, **_TOL[jnp.float32])

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 2, 256, 256, 32,
                       jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
        expect = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, expect, **_TOL[jnp.float32])

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = _qkv(jax.random.PRNGKey(3), 2, 4, 2, 128, 128, 64, dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        assert out.dtype == dtype
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   expect.astype(jnp.float32), **_TOL[dtype])

    def test_ragged_seq_padding(self):
        """seq not a multiple of the block: padded KV rows must not leak."""
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 100, 100, 32,
                       jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, expect, **_TOL[jnp.float32])

    def test_head_dim_256(self):
        """gemma-2b uses head_dim=256."""
        q, k, v = _qkv(jax.random.PRNGKey(5), 1, 4, 1, 128, 128, 256,
                       jnp.float32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, expect, **_TOL[jnp.float32])


class TestSSDKernel:
    @pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64), (64, 64)])
    @pytest.mark.parametrize("g", [1, 2])
    def test_matches_model_reference(self, s, chunk, g):
        b, h, p, n = 2, 4, 32, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
        C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
        y_k, st_k = ssd_chunked(x, dt, A, B, C, chunk=chunk, interpret=True)
        y_r, st_r = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk=chunk)
        np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(st_k, st_r, rtol=2e-4, atol=2e-4)

    def test_chunk_oracle(self):
        """The single-chunk kernel vs the per-chunk pure oracle."""
        c, p, n = 32, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (c, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (c,)))
        A = -jnp.exp(jax.random.normal(ks[2], ()) * 0.3)
        cum = jnp.cumsum(dt * A)
        B = jax.random.normal(ks[3], (c, n)) * 0.3
        C = jax.random.normal(ks[4], (c, n)) * 0.3
        y, st = ref.ssd_chunk_ref(x, dt, cum, B, C)
        assert y.shape == (c, p) and st.shape == (n, p)
        # oracle self-consistency vs the naive recurrence
        s_state = jnp.zeros((n, p))
        ys = []
        prev_cum = 0.0
        for t in range(c):
            decay = jnp.exp(cum[t] - prev_cum)
            s_state = decay * s_state + dt[t] * B[t][:, None] * x[t][None, :]
            ys.append(C[t] @ s_state)
            prev_cum = cum[t]
        np.testing.assert_allclose(y, jnp.stack(ys), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(st, s_state, rtol=1e-4, atol=1e-4)

    def test_decode_step_consistent_with_chunked(self):
        """Sequential O(1) decode steps == the blocked scan."""
        b, s, h, p, n, g = 1, 16, 2, 8, 4, 1
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
        C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
        y_blk, st_blk = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk=8)
        st = jnp.zeros((b, h, n, p))
        ys = []
        for t in range(s):
            y_t, st = ssm_mod.ssd_decode_step(
                st, x[:, t], dt[:, t], A, B[:, t], C[:, t])
            ys.append(y_t)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(y_blk, y_seq, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(st_blk, st, rtol=1e-4, atol=1e-4)

    def test_initial_state_propagates(self):
        """ssd_chunked(init) == running the prefix then the suffix."""
        b, s, h, p, n, g = 1, 32, 2, 8, 4, 1
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
        C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
        y_full, st_full = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk=8)
        _, st_half = ssm_mod.ssd_chunked(
            x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], chunk=8)
        y2, st2 = ssm_mod.ssd_chunked(
            x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:], chunk=8,
            initial_state=st_half)
        np.testing.assert_allclose(y2, y_full[:, 16:], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(st2, st_full, rtol=1e-4, atol=1e-4)

    def test_ragged_seq_pad(self):
        """seq not a multiple of chunk pads with dt=0 (exact)."""
        b, s, h, p, n, g = 1, 20, 2, 8, 4, 1
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
        C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
        y8, st8 = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk=8)
        y20, st20 = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk=20)
        np.testing.assert_allclose(y8, y20, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(st8, st20, rtol=1e-4, atol=1e-4)


class TestRowGather:
    @pytest.mark.parametrize("rows,d", [(16, 64), (64, 128), (8, 512)])
    def test_matches_ref(self, rows, d):
        src = jax.random.normal(jax.random.PRNGKey(0), (rows, d))
        idx = jax.random.randint(jax.random.PRNGKey(1), (32,), -1, rows)
        out = row_gather(src, idx, interpret=True)
        expect = row_gather_ref(src, idx)
        np.testing.assert_allclose(out, expect)

    def test_negative_idx_zeros(self):
        src = jnp.ones((4, 8))
        idx = jnp.array([-1, 0, -1, 3])
        out = row_gather(src, idx, interpret=True)
        np.testing.assert_array_equal(out[0], 0.0)
        np.testing.assert_array_equal(out[2], 0.0)
        np.testing.assert_array_equal(out[1], 1.0)


class TestBucketPack:
    def _roundtrip(self, sizes, tile=128):
        from repro.kernels.bucket_pack import (
            arena_from_leaves, bucket_pack_pallas, bucket_pack_ref,
            build_tile_tables)
        rng = np.random.default_rng(0)
        leaves = [jnp.asarray(rng.normal(size=(s,)), jnp.float32)
                  for s in sizes]
        arena, src_off = arena_from_leaves(leaves, tile=tile)
        # destination: dense tile-aligned concatenation (a bucket buffer)
        dst_off, cur = [], 0
        for s in sizes:
            dst_off.append(cur)
            cur += -(-s // tile) * tile
        padded = cur
        block, valid = build_tile_tables(src_off, dst_off, sizes, padded,
                                         tile=tile)
        out_k = bucket_pack_pallas(arena, jnp.asarray(block),
                                   jnp.asarray(valid), padded, tile=tile,
                                   interpret=True)
        out_r = bucket_pack_ref(arena, block, valid, padded, tile=tile)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        # semantic check: each segment equals its leaf, padding is zero
        for i, s in enumerate(sizes):
            seg = np.asarray(out_k[dst_off[i]: dst_off[i] + s])
            np.testing.assert_array_equal(seg, np.asarray(leaves[i]))
            tail = np.asarray(
                out_k[dst_off[i] + s: dst_off[i] + -(-s // tile) * tile])
            np.testing.assert_array_equal(tail, 0.0)
        return out_k

    @pytest.mark.parametrize("sizes", [[128], [100], [128, 256, 64],
                                       [1, 127, 129, 1000], [512] * 8])
    def test_shapes(self, sizes):
        self._roundtrip(sizes)

    def test_large_tile(self):
        self._roundtrip([2048, 77, 4096], tile=1024)


class TestPagedGather:
    """Paged-KV page gather: Pallas kernel (interpret mode) vs the jnp.take
    lowering vs the scalar oracle, over pool shapes and table patterns
    (unmapped entries, shared-nothing ownership, out-of-order pages)."""

    def _tables(self, rng, b, maxp, np_pages):
        # mapped entries draw WITHOUT replacement (allocator invariant:
        # unique ownership); ~1/3 of entries unmapped
        perm = rng.permutation(np_pages - 1) + 1  # page 0 = trash, unused
        table = np.full((b, maxp), -1, np.int32)
        k = 0
        for i in range(b):
            for p in range(maxp):
                if rng.random() < 0.67 and k < perm.size:
                    table[i, p] = perm[k]
                    k += 1
        return table

    @pytest.mark.parametrize("b,maxp,np_pages,ps,kv,hd", [
        (1, 2, 4, 4, 1, 4),
        (3, 4, 16, 8, 2, 8),
        (2, 3, 5, 2, 4, 16),
    ])
    def test_matches_oracle(self, b, maxp, np_pages, ps, kv, hd):
        from repro.kernels.paged_kv import (
            paged_gather_pallas, paged_gather_ref, paged_gather_take)
        rng = np.random.default_rng(b * 100 + maxp)
        pool = jnp.asarray(rng.normal(size=(np_pages, ps, kv, hd)),
                           jnp.float32)
        table = jnp.asarray(self._tables(rng, b, maxp, np_pages))
        out_k = paged_gather_pallas(pool, table, interpret=True)
        out_t = paged_gather_take(pool, table)
        out_r = paged_gather_ref(pool, table)
        assert out_k.shape == (b, maxp * ps, kv, hd)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_r))

    def test_unmapped_pages_zero(self):
        from repro.kernels.paged_kv import (
            paged_gather_pallas, paged_gather_take)
        pool = jnp.ones((4, 2, 1, 2), jnp.float32)
        table = jnp.asarray([[-1, 2], [1, -1]], jnp.int32)
        for out in (paged_gather_pallas(pool, table, interpret=True),
                    paged_gather_take(pool, table)):
            out = np.asarray(out)
            np.testing.assert_array_equal(out[0, :2], 0.0)   # unmapped
            np.testing.assert_array_equal(out[0, 2:], 1.0)
            np.testing.assert_array_equal(out[1, :2], 1.0)
            np.testing.assert_array_equal(out[1, 2:], 0.0)
