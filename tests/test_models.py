"""Per-architecture smoke + decode-parity tests (reduced configs, CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import synthetic_batch
from repro.models.transformer import Model, init_cache, init_params
from repro.train.trainer import make_train_step, train_state_init


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    """One forward/train step on the reduced same-family variant: output
    shapes correct, loss finite, gradients applied."""
    cfg = get_config(arch + "-smoke")
    batch = synthetic_batch(cfg, 2, 64, seed=0)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state.step) == 1
    # params actually moved
    before = jax.tree_util.tree_leaves(state.params)[0]
    after = jax.tree_util.tree_leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_logit_shapes(arch):
    cfg = get_config(arch + "-smoke")
    batch = synthetic_batch(cfg, 2, 48, seed=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    logits, aux, _ = model.forward(params, batch)
    if cfg.modality == "audio":
        assert logits.shape == (2, cfg.num_codebooks, 48, cfg.vocab_size)
    elif cfg.modality == "vlm":
        assert logits.shape == (2, 48, cfg.vocab_size)  # patches + text
    else:
        assert logits.shape == (2, 48, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# ---------------------------------------------------------------------------
# decode parity: step-by-step decode logits == full-sequence forward logits
# ---------------------------------------------------------------------------

PARITY_ARCHS = ["gemma-2b", "yi-9b", "olmo-1b", "mamba2-780m", "zamba2-7b",
                "mixtral-8x22b", "musicgen-large", "command-r-35b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full forward's logits.

    This is the strongest cache-correctness test: any KV/SSM cache indexing
    bug, RoPE offset bug or ring mis-wrap breaks it.
    """
    cfg = get_config(arch + "-smoke")
    if cfg.moe is not None:
        # drop-free capacity so train/decode paths route identically
        from dataclasses import replace
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts),
                                       capacity_factor_eval=float(cfg.moe.num_experts)))
    S = 24
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    batch = synthetic_batch(cfg, 2, S, seed=2)
    toks = jnp.asarray(batch["tokens"])

    logits_full, _, _ = model.forward(params, {"tokens": toks})

    cache = init_cache(cfg, 2, S + 1, dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        tok_t = toks[..., t: t + 1]  # (B,1) or (B,K,1)
        lg, cache = step(params, tok_t, cache)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=-2)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_matches_forward():
    """Prefill half the sequence, decode the rest: logits == full forward."""
    cfg = get_config("yi-9b-smoke")
    S, P = 32, 16
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    toks = jnp.asarray(synthetic_batch(cfg, 2, S, seed=3)["tokens"])

    logits_full, _, _ = model.forward(params, {"tokens": toks})

    cache = init_cache(cfg, 2, S, dtype=jnp.float32)
    _, _, cache = model.forward(params, {"tokens": toks[:, :P]}, cache=cache)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(P, S):
        lg, cache = step(params, toks[:, t: t + 1], cache)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, P:], np.float32), rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_ssm():
    """Same prefill+decode parity for the attention-free SSM family."""
    cfg = get_config("mamba2-780m-smoke")
    S, P = 32, 16
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    toks = jnp.asarray(synthetic_batch(cfg, 2, S, seed=4)["tokens"])
    logits_full, _, _ = model.forward(params, {"tokens": toks})
    cache = init_cache(cfg, 2, S, dtype=jnp.float32)
    _, _, cache = model.forward(params, {"tokens": toks[:, :P]}, cache=cache)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(P, S):
        lg, cache = step(params, toks[:, t: t + 1], cache)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, P:], np.float32), rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache():
    """Ring (SWA) decode == full-cache decode with the same window, and the
    ring cache stays O(W) in memory."""
    from dataclasses import replace
    base = get_config("yi-9b-smoke")
    W = 8
    cfg = replace(base, sliding_window=W, name="swatest")
    S = 24
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    toks = jnp.asarray(synthetic_batch(cfg, 1, S, seed=5)["tokens"])

    # full cache (max_len == S+1 > W  -> but window masks beyond W anyway)
    cache_ring = init_cache(cfg, 1, S + 1, dtype=jnp.float32)
    assert cache_ring.kv.k.shape[2] == W, "ring cache must be window-sized"
    logits_full, _, _ = model.forward(params, {"tokens": toks})

    step = jax.jit(model.decode_step)
    outs = []
    cache = cache_ring
    for t in range(S):
        lg, cache = step(params, toks[:, t: t + 1], cache)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), rtol=2e-3, atol=2e-3)


def test_vlm_prefill_splices_patches():
    cfg = get_config("phi-3-vision-4.2b-smoke")
    S = 32
    batch = synthetic_batch(cfg, 2, S, seed=6)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    logits, _, _ = model.forward(params, batch)
    assert logits.shape == (2, S, cfg.vocab_size)
    # decode continues after a prefill that includes the image prefix
    cache = init_cache(cfg, 2, S + 4, dtype=jnp.float32)
    _, _, cache = model.forward(params, batch, cache=cache)
    assert int(cache.length) == S
    nxt = jnp.zeros((2, 1), jnp.int32)
    lg, cache2 = jax.jit(model.decode_step)(params, nxt, cache)
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert int(cache2.length) == S + 1


def test_audio_multicodebook_heads():
    cfg = get_config("musicgen-large-smoke")
    batch = synthetic_batch(cfg, 2, 16, seed=7)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    logits, _, _ = model.forward(params, batch)
    assert logits.shape == (2, cfg.num_codebooks, 16, cfg.vocab_size)
    # per-codebook heads differ (not a broadcast of one head)
    l0 = np.asarray(logits[:, 0], np.float32)
    l1 = np.asarray(logits[:, 1], np.float32)
    assert not np.allclose(l0, l1)


def test_nonparametric_norm_has_no_norm_params():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    names = ["/".join(str(getattr(k, "key", k)) for k in kp) for kp, _ in flat]
    assert not any("norm" in n for n in names)


def test_hybrid_shares_attention_weights():
    """zamba2: ONE shared attention block, independent KV per site."""
    cfg = get_config("zamba2-7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert "shared_attn" in params
    cache = init_cache(cfg, 2, 16, dtype=jnp.float32)
    n_sites = cfg.num_layers // cfg.hybrid_attn_every
    assert cache.kv.k.shape[0] == n_sites


def test_decode_kv_expand_numerics():
    """OPT(decode_cache): the TP-matched expanded-KV cache layout must be a
    pure layout change — decode logits identical to the baseline cache."""
    import dataclasses
    base = get_config("yi-9b-smoke")
    S = 20
    params = init_params(base, jax.random.PRNGKey(0))
    toks = jnp.asarray(synthetic_batch(base, 2, S, seed=2)["tokens"])
    outs = {}
    for e in (1, 2):
        cfg = dataclasses.replace(base, decode_kv_expand=e)
        model = Model(cfg)
        cache = init_cache(cfg, 2, S + 1, dtype=jnp.float32)
        assert cache.kv.k.shape[3] == cfg.num_kv_heads * e
        _, _, cache = model.forward(params, {"tokens": toks[:, :10]},
                                    cache=cache)
        step = jax.jit(model.decode_step)
        lgs = []
        for t in range(10, S):
            lg, cache = step(params, toks[:, t: t + 1], cache)
            lgs.append(lg)
        outs[e] = np.asarray(jnp.concatenate(lgs, axis=1), np.float32)
    np.testing.assert_allclose(outs[1], outs[2], rtol=1e-5, atol=1e-5)


def test_remat_dots_matches_block():
    """remat='dots' (selective recomputation) must not change the loss."""
    import dataclasses
    base = get_config("yi-9b-smoke")
    batch = synthetic_batch(base, 2, 32, seed=0)
    vals = {}
    for remat in ("block", "dots"):
        cfg = dataclasses.replace(base, remat=remat)
        state = train_state_init(cfg, jax.random.PRNGKey(0))
        _, m = jax.jit(make_train_step(cfg))(state, batch)
        vals[remat] = float(m["loss"])
    np.testing.assert_allclose(vals["block"], vals["dots"], rtol=1e-5)


def test_moe_dispatch_opt_numerics():
    """OPT(moe_dispatch) has no effect without a mesh and keeps train-step
    numerics with one."""
    cfg = get_config("mixtral-8x22b-smoke").with_opts("moe_dispatch")
    batch = synthetic_batch(cfg, 2, 32, seed=0)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    _, m = jax.jit(make_train_step(cfg))(state, batch)
    base = get_config("mixtral-8x22b-smoke")
    state_b = train_state_init(base, jax.random.PRNGKey(0))
    _, mb = jax.jit(make_train_step(base))(state_b, batch)
    np.testing.assert_allclose(float(m["loss"]), float(mb["loss"]), rtol=1e-6)


def test_kv_fp8_cache():
    """OPT(kv_fp8): fp8 KV storage keeps decode usable — high top-1
    agreement with the f32 cache and finite logits."""
    base = get_config("yi-9b-smoke")
    cfg8 = base.with_opts("kv_fp8")
    S = 24
    params = init_params(base, jax.random.PRNGKey(0))
    toks = jnp.asarray(synthetic_batch(base, 2, S, seed=2)["tokens"])
    outs = {}
    for name, cfg, dt in (("f32", base, jnp.float32),
                          ("fp8", cfg8, jnp.bfloat16)):
        model = Model(cfg)
        cache = init_cache(cfg, 2, S + 1, dtype=dt)
        if name == "fp8":
            assert cache.kv.k.dtype == jnp.float8_e4m3fn
        step = jax.jit(model.decode_step)
        lgs = []
        for t in range(S):
            lg, cache = step(params, toks[:, t: t + 1], cache)
            lgs.append(lg)
        outs[name] = np.asarray(jnp.concatenate(lgs, axis=1), np.float32)
    assert np.isfinite(outs["fp8"]).all()
    agree = (outs["f32"].argmax(-1) == outs["fp8"].argmax(-1)).mean()
    assert agree > 0.85, agree
