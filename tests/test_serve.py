"""Serving-engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import Model, init_params
from repro.serve.engine import (
    Request,
    ServeEngine,
    greedy_sample,
    make_prefill,
    make_serve_step,
    select_tokens,
    temperature_sample,
)


def test_greedy_sample():
    logits = jnp.array([[[0.1, 2.0, -1.0]]])
    assert int(greedy_sample(logits)[0, 0]) == 1


def test_temperature_sample_valid_range():
    key = jax.random.PRNGKey(0)
    logits = jnp.zeros((4, 1, 16))
    toks = temperature_sample(key, logits, temperature=1.0)
    assert toks.shape == (4, 1)
    assert ((toks >= 0) & (toks < 16)).all()


def test_engine_generates():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=5)
            for _ in range(3)]
    done = eng.generate(reqs)
    assert len(done) == 3
    for r in done:
        assert r.generated is not None
        assert r.generated.shape == (5,)
        assert ((r.generated >= 0) & (r.generated < cfg.vocab_size)).all()


def test_engine_greedy_is_deterministic():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    def gen():
        rs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=6)
              for _ in range(2)]
        return [r.generated.copy() for r in eng.generate(rs)]
    a, b = gen(), gen()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_engine_audio_batch():
    cfg = get_config("musicgen-large-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    K = cfg.num_codebooks
    reqs = [Request(prompt=np.zeros((K, 4), np.int32), max_new_tokens=3)]
    done = eng.generate(reqs)
    assert done[0].generated.shape == (K, 3)


def test_select_tokens_mixes_greedy_and_sampled_rows():
    key = jax.random.PRNGKey(0)
    logits = jnp.zeros((3, 1, 16)).at[:, 0, 5].set(4.0)
    temps = jnp.asarray([0.0, 1.0, 0.0])
    toks = select_tokens(logits, temps, key)
    assert toks.shape == (3, 1)
    assert int(toks[0, 0]) == 5 and int(toks[2, 0]) == 5  # greedy rows
    assert ((toks >= 0) & (toks < 16)).all()


@pytest.fixture(scope="module")
def olmo_setup():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _solo_tokens(cfg, params, req: Request, max_len=64):
    """Reference: the request decoded alone in a batch of one."""
    eng = ServeEngine(cfg, params, batch_size=1, max_len=max_len)
    ref = Request(prompt=req.prompt.copy(),
                  max_new_tokens=req.max_new_tokens,
                  stop_token=req.stop_token)
    eng.generate([ref])
    return ref.generated


def test_mixed_length_batch_matches_solo(olmo_setup):
    """Regression for the min-length truncation bug: a batch of unequal
    prompt lengths must produce, for every request, exactly the tokens it
    would produce alone (left-padding + masked prefill)."""
    cfg, params = olmo_setup
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32), max_new_tokens=6)
            for plen in (3, 11, 7)]
    eng = ServeEngine(cfg, params, batch_size=3, max_len=64)
    eng.generate(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            r.generated, _solo_tokens(cfg, params, r),
            err_msg=f"prompt len {r.prompt.shape[-1]} corrupted by batching")


def test_cache_overflow_rejected(olmo_setup):
    """plen + max_new_tokens > max_len must raise at generate() time, not
    silently wrap the cache write cursor."""
    cfg, params = olmo_setup
    eng = ServeEngine(cfg, params, batch_size=2, max_len=16)
    ok = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=8)
    bad = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=9)
    with pytest.raises(ValueError, match="exceeds the cache depth"):
        eng.generate([ok, bad])
    assert bad.generated is None  # rejected before any decoding
    eng.generate([ok])            # the boundary case fits exactly
    assert ok.generated.shape == (8,)


def test_per_request_max_new_tokens(olmo_setup):
    """Each request decodes ITS budget — not max() over the batch."""
    cfg, params = olmo_setup
    reqs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=n)
            for n in (2, 7, 4)]
    eng = ServeEngine(cfg, params, batch_size=3, max_len=32)
    eng.generate(reqs)
    assert [r.generated.shape[-1] for r in reqs] == [2, 7, 4]
    for r in reqs:
        np.testing.assert_array_equal(
            r.generated, _solo_tokens(cfg, params, r, max_len=32))


def test_stop_token_early_exit(olmo_setup):
    """A request finishes at its stop token; tokens before it match the
    un-stopped run."""
    cfg, params = olmo_setup
    base = Request(prompt=np.arange(6, dtype=np.int32), max_new_tokens=8)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    eng.generate([base])
    assert base.generated.shape == (8,)
    # first position whose token hasn't occurred before = unambiguous stop
    j = next(j for j in range(1, 8)
             if base.generated[j] not in base.generated[:j])
    stop = int(base.generated[j])
    stopped = Request(prompt=np.arange(6, dtype=np.int32), max_new_tokens=8,
                      stop_token=stop)
    other = Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=8)
    eng.generate([stopped, other])
    np.testing.assert_array_equal(stopped.generated, base.generated[:j])
    assert other.generated.shape == (8,)


def test_continuous_batching_recycles_slots(olmo_setup):
    """More requests than slots with unequal lengths/budgets: every request
    completes with exactly its solo tokens (early admission into freed
    slots must not leak the previous occupant's cache)."""
    cfg, params = olmo_setup
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32), max_new_tokens=n)
            for plen, n in ((5, 3), (9, 6), (4, 8), (7, 2), (6, 5))]
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    eng.generate(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            r.generated, _solo_tokens(cfg, params, r),
            err_msg=f"request {i} corrupted by slot recycling")


def test_decode_matches_forward_argmax(olmo_setup):
    """Conformance: N greedy decode steps equal the argmax tail of one full
    forward over prompt + generated tokens (teacher-forcing check)."""
    cfg, params = olmo_setup
    req = Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=6)
    eng = ServeEngine(cfg, params, batch_size=1, max_len=32)
    eng.generate([req])
    full = np.concatenate([req.prompt, req.generated[:-1]])
    model = Model(cfg)
    logits, _, _ = jax.jit(model.forward)(params,
                                          {"tokens": jnp.asarray(full)[None]})
    want = np.asarray(jnp.argmax(logits[0, req.prompt.shape[-1] - 1:], -1))
    np.testing.assert_array_equal(req.generated, want)


def test_ring_cache_mixed_lengths_grouped():
    """Sliding-window (ring cache) archs can't left-pad; the engine must
    fall back to equal-length groups and still serve mixed lengths."""
    cfg = get_config("mixtral-8x22b-smoke")   # sliding_window=64
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=96)  # 96 > window
    assert eng._ring and not eng._padded_ok
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32), max_new_tokens=3)
            for plen in (4, 8, 4)]
    eng.generate(reqs)
    solo = ServeEngine(cfg, params, batch_size=1, max_len=96)
    for r in reqs:
        assert r.generated.shape == (3,)
        ref = Request(prompt=r.prompt.copy(), max_new_tokens=3)
        solo.generate([ref])
        np.testing.assert_array_equal(r.generated, ref.generated)


def test_temperature_zero_matches_greedy(olmo_setup):
    cfg, params = olmo_setup
    prompt = np.arange(6, dtype=np.int32)
    greedy = Request(prompt=prompt.copy(), max_new_tokens=4)
    tzero = Request(prompt=prompt.copy(), max_new_tokens=4, temperature=0.0)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, temperature=0.7)
    # engine default 0.7 applies only where the request doesn't override
    eng.generate([tzero])
    np.testing.assert_array_equal(tzero.generated,
                                  _solo_tokens(cfg, params, greedy))


def test_temperature_sampling_decodes_valid_tokens(olmo_setup):
    cfg, params = olmo_setup
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, seed=1)
    reqs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=5,
                    temperature=1.0) for _ in range(2)]
    eng.generate(reqs)
    for r in reqs:
        assert r.generated.shape == (5,)
        assert ((r.generated >= 0) & (r.generated < cfg.vocab_size)).all()


def test_serve_step_matches_engine_stepping():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.models.transformer import init_cache
    cache = init_cache(cfg, 1, 16, dtype=jnp.float32)
    prompt = jnp.arange(4, dtype=jnp.int32)[None]
    nxt, cache = jax.jit(make_prefill(cfg))(params, {"tokens": prompt}, cache)
    step = jax.jit(make_serve_step(cfg))
    seq = [int(nxt[0, 0])]
    for _ in range(4):
        nxt, cache = step(params, nxt, cache)
        seq.append(int(nxt[0, 0]))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                      cache_dtype=jnp.float32)
    [req] = eng.generate([Request(prompt=np.arange(4, dtype=np.int32),
                                  max_new_tokens=5)])
    np.testing.assert_array_equal(np.array(seq), req.generated)


# ---------------------------------------------------------------------------
# paged KV cache engine
# ---------------------------------------------------------------------------

def test_paged_mixed_length_batch_matches_solo(olmo_setup):
    """Paged cache, mixed prompt lengths: every request produces exactly the
    tokens the CONTIGUOUS single-request engine produces — the two cache
    layouts are token-identical by construction."""
    cfg, params = olmo_setup
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32), max_new_tokens=6)
            for plen in (3, 11, 7)]
    eng = ServeEngine(cfg, params, batch_size=3, max_len=64, paged=True,
                      page_size=8, num_pages=13)
    eng.generate(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            r.generated, _solo_tokens(cfg, params, r),
            err_msg=f"prompt len {r.prompt.shape[-1]} corrupted by paging")


def test_paged_recycling_reclaims_pages(olmo_setup):
    """More requests than slots: tokens match solo AND every page is back
    in the pool when the run drains (per-slot compaction for free)."""
    cfg, params = olmo_setup
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                        dtype=np.int32), max_new_tokens=n)
            for plen, n in ((5, 3), (9, 6), (4, 8), (7, 2), (6, 5))]
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64, paged=True,
                      page_size=8, num_pages=13)
    eng.generate(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            r.generated, _solo_tokens(cfg, params, r),
            err_msg=f"request {i} corrupted by paged slot recycling")
    owner = np.asarray(eng._owner)
    assert owner[0] == -2 and (owner[1:] == -1).all(), \
        f"pages leaked after drain: {owner}"


def test_paged_lower_resident_bytes_than_contiguous(olmo_setup):
    """At equal traffic a right-sized page pool keeps fewer resident cache
    bytes than the contiguous (batch, max_len) cache — the paging payoff."""
    cfg, params = olmo_setup
    def mk():
        rng = np.random.default_rng(5)
        return [Request(prompt=rng.integers(0, cfg.vocab_size, (p,),
                                            dtype=np.int32),
                        max_new_tokens=4) for p in (6, 9, 5, 8)]
    eng_c = ServeEngine(cfg, params, batch_size=4, max_len=128)
    eng_p = ServeEngine(cfg, params, batch_size=4, max_len=128, paged=True,
                        page_size=8, num_pages=13)
    a, b = mk(), mk()
    eng_c.generate(a)
    eng_p.generate(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.generated, y.generated)
    assert 0 < eng_p.cache_bytes_resident < eng_c.cache_bytes_resident


def test_paged_stop_token_and_budgets(olmo_setup):
    """Per-request budgets and stop tokens behave identically paged — the
    stop-token finish (record() without appending) must also reclaim the
    slot's pages mid-page."""
    cfg, params = olmo_setup
    reqs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=n)
            for n in (2, 7, 4)]
    eng = ServeEngine(cfg, params, batch_size=3, max_len=32, paged=True,
                      page_size=4, num_pages=13)
    eng.generate(reqs)
    assert [r.generated.shape[-1] for r in reqs] == [2, 7, 4]
    for r in reqs:
        np.testing.assert_array_equal(
            r.generated, _solo_tokens(cfg, params, r, max_len=32))

    # a stop token that actually fires: truncates at the un-stopped run's
    # first repeat-free position, and the drained pool holds no pages
    base = reqs[1]                        # 7 greedy tokens
    j = next(j for j in range(1, 7)
             if base.generated[j] not in base.generated[:j])
    stopped = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=7,
                      stop_token=int(base.generated[j]))
    other = Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=7)
    eng.generate([stopped, other])
    np.testing.assert_array_equal(stopped.generated, base.generated[:j])
    assert other.generated.shape == (7,)
    owner = np.asarray(eng._owner)
    assert owner[0] == -2 and (owner[1:] == -1).all(), \
        f"stop-token finish leaked pages: {owner}"


def test_paged_pool_too_small_rejected(olmo_setup):
    """A request whose worst-case page span exceeds the pool must be
    rejected at generate() time, not starve the allocator mid-decode."""
    cfg, params = olmo_setup
    eng = ServeEngine(cfg, params, batch_size=1, max_len=64, paged=True,
                      page_size=8, num_pages=4)  # 3 allocatable pages
    ok = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=16)
    bad = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=17)
    with pytest.raises(ValueError, match="grow the pool"):
        eng.generate([bad])
    eng.generate([ok])
    assert ok.generated.shape == (16,)


def test_paged_falls_back_for_ring_cache():
    """Sliding-window (ring) archs have no paged layout: the engine keeps
    the grouped contiguous fallback and still serves correctly."""
    cfg = get_config("mixtral-8x22b-smoke")   # sliding_window=64
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=96, paged=True)
    assert eng._ring and not eng._paged
    reqs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=3)
            for _ in range(2)]
    eng.generate(reqs)
    for r in reqs:
        assert r.generated.shape == (3,)
