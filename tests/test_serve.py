"""Serving-engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serve.engine import (
    Request,
    ServeEngine,
    greedy_sample,
    make_prefill,
    make_serve_step,
    temperature_sample,
)


def test_greedy_sample():
    logits = jnp.array([[[0.1, 2.0, -1.0]]])
    assert int(greedy_sample(logits)[0, 0]) == 1


def test_temperature_sample_valid_range():
    key = jax.random.PRNGKey(0)
    logits = jnp.zeros((4, 1, 16))
    toks = temperature_sample(key, logits, temperature=1.0)
    assert toks.shape == (4, 1)
    assert ((toks >= 0) & (toks < 16)).all()


def test_engine_generates():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=5)
            for _ in range(3)]
    done = eng.generate(reqs)
    assert len(done) == 3
    for r in done:
        assert r.generated is not None
        assert r.generated.shape == (5,)
        assert ((r.generated >= 0) & (r.generated < cfg.vocab_size)).all()


def test_engine_greedy_is_deterministic():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    def gen():
        rs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=6)
              for _ in range(2)]
        return [r.generated.copy() for r in eng.generate(rs)]
    a, b = gen(), gen()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_engine_audio_batch():
    cfg = get_config("musicgen-large-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    K = cfg.num_codebooks
    reqs = [Request(prompt=np.zeros((K, 4), np.int32), max_new_tokens=3)]
    done = eng.generate(reqs)
    assert done[0].generated.shape == (K, 3)


def test_serve_step_matches_engine_stepping():
    cfg = get_config("olmo-1b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.models.transformer import init_cache
    cache = init_cache(cfg, 1, 16, dtype=jnp.float32)
    prompt = jnp.arange(4, dtype=jnp.int32)[None]
    nxt, cache = jax.jit(make_prefill(cfg))(params, {"tokens": prompt}, cache)
    step = jax.jit(make_serve_step(cfg))
    seq = [int(nxt[0, 0])]
    for _ in range(4):
        nxt, cache = step(params, nxt, cache)
        seq.append(int(nxt[0, 0]))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                      cache_dtype=jnp.float32)
    [req] = eng.generate([Request(prompt=np.arange(4, dtype=np.int32),
                                  max_new_tokens=5)])
    np.testing.assert_array_equal(np.array(seq), req.generated)
