"""Fast bucketed-reduction path: kernels, plan cache, and reduction modes.

Covers the three tentpole pieces of the fast path:

* ``bucket_pack_pallas`` / ``bucket_unpack_pallas`` round-trip against the
  jnp oracles in interpret mode (plus the vectorized gather lowering);
* ``get_comm_plan`` persistent-cache hit/reuse semantics;
* ``reduce_gradients`` pack/reduction knob equivalence (single-device mesh
  here; the 8-device numerics live in tests/_multidev_checks.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import (
    get_comm_plan,
    plan_buckets,
    plan_cache_clear,
    plan_cache_stats,
    reduce_gradients,
)
from repro.core.bucketing import _pack_bucket_dma, pack_bucket, unpack_bucket
from repro.kernels.bucket_pack import (
    arena_from_leaves,
    arena_layout,
    bucket_pack_gather,
    bucket_pack_pallas,
    bucket_pack_ref,
    bucket_unpack_gather,
    bucket_unpack_pallas,
    bucket_unpack_ref,
    build_tile_tables,
)

TILE = 16  # small tile: interpret mode grid-steps in Python


def _tree(shapes, dtype=jnp.float32):
    return {f"leaf{i}": (jnp.arange(int(np.prod(s)), dtype=dtype)
                         .reshape(s) * (i + 1))
            for i, s in enumerate(shapes)}


def _plan_tables(tree, nb, tile=TILE):
    """(plan, arena, per-bucket pack tables, unpack table, arena meta)."""
    plan = plan_buckets(tree, nb, align=tile, slot_align=tile)
    leaves = jax.tree_util.tree_leaves(tree)
    sizes = [l.size for l in leaves]
    arena_offs, arena_size = arena_layout(sizes, tile)
    arena, offs = arena_from_leaves(leaves, tile=tile, dtype=jnp.float32)
    np.testing.assert_array_equal(offs, arena_offs)
    assert arena.shape[0] == arena_size
    pack_tables = [build_tile_tables(
        [arena_offs[s.index] for s in b.slots],
        [s.offset for s in b.slots],
        [s.size for s in b.slots], b.padded_size, tile)
        for b in plan.buckets]
    bases = np.cumsum([0] + [b.padded_size for b in plan.buckets])
    src, dst, szs = [], [], []
    for bi, b in enumerate(plan.buckets):
        for s in b.slots:
            src.append(int(bases[bi]) + s.offset)
            dst.append(int(arena_offs[s.index]))
            szs.append(s.size)
    unpack_table = build_tile_tables(src, dst, szs, arena_size, tile)
    return plan, leaves, arena, pack_tables, unpack_table, arena_offs, arena_size


class TestPallasKernels:
    SHAPES = [
        [(7,), (33,), (4, 5)],
        [(1,)],
        [(16,), (16,), (16,), (3, 3, 3)],
        [(100,), (2,), (50,)],
    ]

    @pytest.mark.parametrize("shapes", SHAPES)
    @pytest.mark.parametrize("nb", [1, 2])
    def test_pack_kernel_matches_oracle(self, shapes, nb):
        tree = _tree(shapes)
        _, _, arena, pack_tables, _, _, _ = _plan_tables(tree, nb)
        for (blk, val), b in zip(pack_tables,
                                 plan_buckets(tree, nb, align=TILE,
                                              slot_align=TILE).buckets):
            out_k = bucket_pack_pallas(arena, jnp.asarray(blk),
                                       jnp.asarray(val), b.padded_size,
                                       tile=TILE, interpret=True)
            out_r = bucket_pack_ref(arena, blk, val, b.padded_size, tile=TILE)
            out_g = bucket_pack_gather(arena, blk, val, b.padded_size,
                                       tile=TILE)
            np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
            np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out_r))

    @pytest.mark.parametrize("shapes", SHAPES)
    def test_pack_unpack_roundtrip_interpret(self, shapes):
        """arena -> per-bucket pack -> concat -> unpack == arena."""
        tree = _tree(shapes)
        plan, leaves, arena, pack_tables, unpack_table, arena_offs, \
            arena_size = _plan_tables(tree, 2)
        packed = [bucket_pack_pallas(arena, jnp.asarray(t[0]),
                                     jnp.asarray(t[1]), b.padded_size,
                                     tile=TILE, interpret=True)
                  for t, b in zip(pack_tables, plan.buckets)]
        allp = jnp.concatenate(packed) if len(packed) > 1 else packed[0]
        out_k = bucket_unpack_pallas(allp, jnp.asarray(unpack_table[0]),
                                     jnp.asarray(unpack_table[1]),
                                     arena_size, tile=TILE, interpret=True)
        out_r = bucket_unpack_ref(allp, *unpack_table, arena_size, tile=TILE)
        out_g = bucket_unpack_gather(allp, *unpack_table, arena_size,
                                     tile=TILE)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out_r))
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(arena))
        # and each leaf slices back exactly
        for i, leaf in enumerate(leaves):
            off = int(arena_offs[i])
            got = out_k[off: off + leaf.size].reshape(leaf.shape)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(leaf))

    def test_dma_pack_matches_concat_pack(self):
        """The non-TPU DUS lowering == pack_bucket on slot-aligned plans."""
        tree = _tree([(7,), (40,), (3, 9), (2,)])
        plan = plan_buckets(tree, 2, align=TILE, slot_align=TILE)
        leaves = jax.tree_util.tree_leaves(tree)
        for b in plan.buckets:
            dma = _pack_bucket_dma(leaves, b, jnp.float32)
            ref = pack_bucket(leaves, b, dtype=jnp.float32)
            np.testing.assert_array_equal(np.asarray(dma), np.asarray(ref))

    def test_slot_aligned_plan_layout(self):
        tree = _tree([(5,), (17,), (100,)])
        plan = plan_buckets(tree, 2, align=TILE, slot_align=TILE)
        for b in plan.buckets:
            assert b.padded_size % TILE == 0
            for s in b.slots:
                assert s.offset % TILE == 0
        # roundtrip through pack/unpack still exact with gap padding
        leaves = jax.tree_util.tree_leaves(tree)
        rec = {}
        for b in plan.buckets:
            flat = pack_bucket(leaves, b)
            for idx, val in unpack_bucket(flat, b):
                rec[idx] = val
        for i, leaf in enumerate(leaves):
            np.testing.assert_array_equal(np.asarray(rec[i]), np.asarray(leaf))


class TestPlanCache:
    def setup_method(self):
        plan_cache_clear()

    def teardown_method(self):
        plan_cache_clear()

    def _grads(self, n=5, base=8):
        return {f"g{i}": jnp.ones((base + i,)) for i in range(n)}

    def test_hit_returns_same_object(self):
        g = self._grads()
        a = get_comm_plan(g, num_streams=2)
        b = get_comm_plan(g, num_streams=2)
        assert a is b
        s = plan_cache_stats()
        assert s["hits"] == 1 and s["misses"] == 1 and s["builds"] == 1

    def test_key_includes_shapes_and_knobs(self):
        a = get_comm_plan(self._grads(), num_streams=2)
        b = get_comm_plan(self._grads(base=9), num_streams=2)   # new shapes
        c = get_comm_plan(self._grads(), num_streams=3)         # new knob
        d = get_comm_plan(self._grads(), num_streams=2, pack="pallas")
        assert len({id(x) for x in (a, b, c, d)}) == 4
        assert plan_cache_stats()["size"] == 4

    def test_non_persistent_bypasses_cache(self):
        g = self._grads()
        a = get_comm_plan(g, num_streams=2, persistent=False)
        b = get_comm_plan(g, num_streams=2, persistent=False)
        assert a is not b
        s = plan_cache_stats()
        assert s["size"] == 0 and s["builds"] == 2 and s["hits"] == 0

    def test_plan_contexts_cover_buckets(self):
        cp = get_comm_plan(self._grads(), num_streams=3)
        assert len(cp.contexts) == cp.plan.num_buckets
        assert len({c.name for c in cp.contexts}) == len(cp.contexts)

    def test_runtime_is_fresh_per_call(self):
        """Tokens are trace-local: each trace must get its own engine."""
        cp = get_comm_plan(self._grads(), num_streams=2)
        assert cp.runtime() is not cp.runtime()
        assert cp.runtime().world is cp.world

    def test_pallas_tables_cached_once(self):
        cp = get_comm_plan(self._grads(), num_streams=2, pack="pallas")
        t1 = cp.tables
        t2 = cp.tables
        assert t1 is t2
        tile, offs, size, pack_tables, unpack_table = t1
        assert size % tile == 0
        assert len(pack_tables) == cp.plan.num_buckets


class TestPlanCacheTrainStep:
    """CommPlan cache behaviour through the REAL train_step, for both
    comm schedules: repeated (eager, hence re-traced) steps HIT the cache;
    a knob change (num_streams), a schedule change, and a shape change
    (different arch => different grad shapes) each MISS and build anew."""

    def setup_method(self):
        plan_cache_clear()

    def teardown_method(self):
        plan_cache_clear()

    @staticmethod
    def _step_and_state(cfg, mesh, *, schedule, num_streams=2):
        from repro.train.trainer import make_train_step, train_state_init

        step = make_train_step(cfg, mesh=mesh, comm="vci",
                               num_streams=num_streams, num_vcis=2,
                               token_impl="data", schedule=schedule)
        state = train_state_init(cfg, jax.random.PRNGKey(0), mesh=mesh,
                                 num_streams=num_streams, schedule=schedule)
        return step, state

    @pytest.mark.parametrize("schedule", ["post", "overlap"])
    def test_repeated_steps_hit_then_knob_and_shape_miss(self, schedule):
        from repro.compat import set_mesh
        from repro.configs import get_config
        from repro.data.pipeline import synthetic_batch

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        cfg = get_config("olmo-1b-smoke")
        step, state = self._step_and_state(cfg, mesh, schedule=schedule)
        plan_cache_clear()
        with set_mesh(mesh):
            # eager (unjitted) calls re-trace every step: each trace asks
            # for the plan again, so steps 2..3 must hit the cache.
            for i in range(3):
                state, _ = step(state, synthetic_batch(cfg, 2, 16, seed=i))
        s = plan_cache_stats()
        assert s["misses"] == 1 and s["builds"] == 1, s
        assert s["hits"] == 2 and s["size"] == 1, s

        # knob change: same tree, different num_streams -> new plan
        step3, state3 = self._step_and_state(cfg, mesh, schedule=schedule,
                                             num_streams=3)
        with set_mesh(mesh):
            step3(state3, synthetic_batch(cfg, 2, 16, seed=0))
        s = plan_cache_stats()
        assert s["misses"] == 2 and s["size"] == 2, s

        # shape change: different arch -> different grad shapes -> new plan
        cfg2 = get_config("gemma-2b-smoke")
        step_g, state_g = self._step_and_state(cfg2, mesh, schedule=schedule)
        with set_mesh(mesh):
            step_g(state_g, synthetic_batch(cfg2, 2, 16, seed=0))
        s = plan_cache_stats()
        assert s["misses"] == 3 and s["builds"] == 3 and s["size"] == 3, s

    def test_schedules_key_separate_plans(self):
        """post and overlap must never share a cached plan: the overlap
        partition is contiguous-by-use-order, post is size-balanced."""
        from repro.compat import set_mesh
        from repro.configs import get_config
        from repro.data.pipeline import synthetic_batch

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        cfg = get_config("olmo-1b-smoke")
        batch = synthetic_batch(cfg, 2, 16, seed=0)
        for schedule in ("post", "overlap"):
            step, state = self._step_and_state(cfg, mesh, schedule=schedule)
            with set_mesh(mesh):
                step(state, batch)
        s = plan_cache_stats()
        assert s["misses"] == 2 and s["builds"] == 2 and s["size"] == 2, s
        assert s["hits"] == 0, s


class TestReducePaths:
    """Single-device mesh: the reduction is the identity (axis size 1), so
    every pack/reduction combination must reproduce the input tree."""

    def setup_method(self):
        plan_cache_clear()

    @pytest.mark.parametrize("pack", ["xla", "pallas"])
    @pytest.mark.parametrize("reduction", ["all_reduce", "reduce_scatter"])
    def test_identity_on_one_device(self, pack, reduction):
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        tree = _tree([(4, 8), (130,), (3,)])
        spec = jax.tree_util.tree_map(lambda _: P(), tree)

        def run(tr):
            cp = get_comm_plan(tr, num_streams=2, num_vcis=3, pack=pack)
            rt = cp.runtime()
            return reduce_gradients(rt, tr, cp, axis="data", mean=True,
                                    pack=pack, reduction=reduction)

        f = jax.jit(shard_map(run, mesh=mesh, in_specs=(spec,),
                              out_specs=spec, check_vma=False))
        got = f(tree)
        for g, e in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                       rtol=1e-6)

    def test_bad_knobs_raise(self):
        cp = get_comm_plan(_tree([(4,)]), num_streams=1)
        with pytest.raises(ValueError):
            reduce_gradients(cp.runtime(), _tree([(4,)]), cp, pack="nope")
        with pytest.raises(ValueError):
            reduce_gradients(cp.runtime(), _tree([(4,)]), cp,
                             reduction="nope")
