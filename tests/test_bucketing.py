"""Unit + property tests for gradient bucketing (paper §4.3 analogues)."""

import jax
import jax.numpy as jnp
import numpy as np

# hypothesis is optional: the unit tests below run without it, the property
# tests skip cleanly (collection must never hard-fail on the missing dep).
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dep absent in minimal envs
    HAVE_HYPOTHESIS = False

from repro.core.bucketing import (
    TILE,
    bucket_ready_order,
    pack_bucket,
    plan_buckets,
    unpack_bucket,
)


def _tree(shapes):
    return {f"leaf{i}": jnp.arange(int(np.prod(s)), dtype=jnp.float32).reshape(s) * (i + 1)
            for i, s in enumerate(shapes)}


class TestPlan:
    def test_every_leaf_exactly_once(self):
        tree = _tree([(4, 8), (16,), (2, 3, 5), (7,), (128, 2)])
        plan = plan_buckets(tree, 3)
        seen = sorted(s.index for b in plan.buckets for s in b.slots)
        assert seen == list(range(5))

    def test_num_buckets_capped_by_leaves(self):
        tree = _tree([(4,), (5,)])
        plan = plan_buckets(tree, 10)
        assert plan.num_buckets == 2

    def test_alignment(self):
        tree = _tree([(100,), (3,), (77,)])
        plan = plan_buckets(tree, 2, align=TILE)
        for b in plan.buckets:
            assert b.padded_size % TILE == 0
        plan1 = plan_buckets(tree, 2, align=1)
        assert plan1.total_padded <= plan.total_padded

    def test_greedy_balance(self):
        # equal-size leaves must spread evenly
        tree = _tree([(64,)] * 8)
        plan = plan_buckets(tree, 4, align=1)
        loads = [sum(s.size for s in b.slots) for b in plan.buckets]
        assert max(loads) == min(loads) == 128

    def test_offsets_contiguous(self):
        tree = _tree([(10,), (20,), (30,), (40,)])
        plan = plan_buckets(tree, 2, align=1)
        for b in plan.buckets:
            off = 0
            for s in b.slots:
                assert s.offset == off
                off += s.size


class TestContigPartition:
    """The overlap layout: buckets contiguous in leaf-use (flatten) order."""

    def test_buckets_are_contiguous_runs(self):
        tree = _tree([(7,)] * 11)
        plan = plan_buckets(tree, 4, align=1, partition="contig")
        nxt = 0
        for b in plan.buckets:
            idxs = [s.index for s in b.slots]
            assert idxs == list(range(nxt, nxt + len(idxs))), idxs
            nxt += len(idxs)
        assert nxt == 11
        assert all(b.slots for b in plan.buckets)  # no empty buckets

    def test_roughly_balanced(self):
        tree = _tree([(64,)] * 8)
        plan = plan_buckets(tree, 4, align=1, partition="contig")
        loads = [sum(s.size for s in b.slots) for b in plan.buckets]
        assert max(loads) == min(loads) == 128

    def test_skewed_sizes_every_bucket_nonempty(self):
        tree = _tree([(1000,), (1,), (1,), (1,), (1,)])
        plan = plan_buckets(tree, 3, align=1, partition="contig")
        assert plan.num_buckets == 3
        assert all(b.slots for b in plan.buckets)
        seen = sorted(s.index for b in plan.buckets for s in b.slots)
        assert seen == list(range(5))

    def test_unknown_partition_raises(self):
        try:
            plan_buckets(_tree([(4,)]), 1, partition="nope")
        except ValueError:
            return
        raise AssertionError("expected ValueError")


class TestReadyOrder:
    def test_last_used_leaves_ready_first(self):
        # contig partition, use order == flatten order: the bucket holding
        # the HIGHEST leaf indices is fully differentiated first.
        tree = _tree([(8,)] * 9)
        plan = plan_buckets(tree, 3, align=1, partition="contig")
        assert bucket_ready_order(plan) == (2, 1, 0)

    def test_custom_use_order(self):
        tree = _tree([(8,)] * 4)
        plan = plan_buckets(tree, 2, align=1, partition="contig")
        # reversed use order flips readiness: bucket 0's leaves are now the
        # last-used (first-differentiated) ones
        assert bucket_ready_order(plan, leaf_use_order=[3, 2, 1, 0]) == (0, 1)

    def test_size_partition_ready_order_is_valid_permutation(self):
        tree = _tree([(17,), (3,), (64,), (5,), (2, 2)])
        plan = plan_buckets(tree, 3)
        order = bucket_ready_order(plan)
        assert sorted(order) == list(range(plan.num_buckets))

    def test_bad_use_order_raises(self):
        tree = _tree([(4,), (4,)])
        plan = plan_buckets(tree, 2, align=1)
        try:
            bucket_ready_order(plan, leaf_use_order=[0, 0])
        except ValueError:
            return
        raise AssertionError("expected ValueError")


class TestPackUnpack:
    def test_roundtrip_exact(self):
        tree = _tree([(4, 8), (16,), (2, 3, 5), (1,)])
        leaves, _ = jax.tree_util.tree_flatten(tree)
        plan = plan_buckets(tree, 2)
        recovered = {}
        for b in plan.buckets:
            flat = pack_bucket(leaves, b)
            assert flat.shape == (b.padded_size,)
            for idx, val in unpack_bucket(flat, b):
                recovered[idx] = val
        for i, leaf in enumerate(leaves):
            np.testing.assert_array_equal(recovered[i], leaf)

    def test_padding_is_zero(self):
        tree = _tree([(5,)])
        plan = plan_buckets(tree, 1, align=16)
        flat = pack_bucket(jax.tree_util.tree_leaves(tree), plan.buckets[0])
        np.testing.assert_array_equal(flat[5:], 0.0)

    def test_dtype_cast_roundtrip(self):
        leaves = [jnp.ones((4,), jnp.bfloat16) * 1.5]
        plan = plan_buckets(leaves, 1)
        flat = pack_bucket(leaves, plan.buckets[0], dtype=jnp.float32)
        assert flat.dtype == jnp.float32
        (idx, val), = unpack_bucket(flat, plan.buckets[0])
        assert val.dtype == jnp.bfloat16
        np.testing.assert_array_equal(val, leaves[0])


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        shapes=st.lists(
            st.lists(st.integers(1, 6), min_size=0, max_size=3), min_size=1,
            max_size=8),
        nb=st.integers(1, 5),
        align=st.sampled_from([1, 8, 128]),
    )
    def test_property_bucketing_roundtrip(shapes, nb, align):
        """For ANY pytree of shapes, bucketing + pack + unpack is the identity."""
        leaves = [np.random.default_rng(i).normal(size=s).astype(np.float32)
                  for i, s in enumerate(shapes)]
        tree = {f"l{i}": jnp.asarray(a) for i, a in enumerate(leaves)}
        flat_leaves, treedef = jax.tree_util.tree_flatten(tree)
        plan = plan_buckets(tree, nb, align=align)
        out = [None] * len(flat_leaves)
        for b in plan.buckets:
            buf = pack_bucket(flat_leaves, b)
            assert buf.shape[0] % align == 0
            for idx, val in unpack_bucket(buf, b):
                out[idx] = val
        rebuilt = jax.tree_util.tree_unflatten(treedef, out)
        for a, b_ in zip(jax.tree_util.tree_leaves(tree),
                         jax.tree_util.tree_leaves(rebuilt)):
            np.testing.assert_array_equal(a, b_)

    @settings(max_examples=25, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 2048), min_size=1, max_size=20),
        nb=st.integers(1, 8),
    )
    def test_property_balance_bound(sizes, nb):
        """Greedy LPT bound: max load <= mean + max_item (classic guarantee)."""
        tree = [jnp.zeros((s,)) for s in sizes]
        plan = plan_buckets(tree, nb, align=1)
        loads = [sum(s.size for s in b.slots) for b in plan.buckets]
        mean = sum(sizes) / len(plan.buckets)
        assert max(loads) <= mean + max(sizes) + 1e-9
