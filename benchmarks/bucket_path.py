"""Bucketed-gradient fast-path ablation — this repo's §4.3 analogue.

Three orthogonal knobs x the real ``reduce_gradients`` hot path on a
gradient-shaped pytree (a model parameter tree with the layer stack
unstacked into per-layer leaves — the DDP many-small-messages regime the
paper's message-rate story is about):

* ``plan``       per_step (seed: rebuild BucketPlan + CommWorld + contexts
                 inside every trace) vs persistent (``get_comm_plan`` cache
                 — the per-VCI request-cache analogue).
* ``pack``       xla (O(leaves) concat chain per bucket) vs pallas (the
                 tile/slot-aligned DMA layout: ``bucket_pack_pallas`` /
                 ``bucket_unpack_pallas`` tile-gather kernels on TPU,
                 per-slot dynamic_update_slice DMA writes off-TPU).
* ``reduction``  all_reduce vs reduce_scatter + all_gather per bucket.

Reported per cell:

* ``ms_per_step``  — compiled steady-state wall clock per step (median).
  The headline: on the 8-device CPU mesh the concat-chain pack
  materializes a copy per operand and dominates the step, so the
  pallas/DMA layout roughly halves the step (see BENCH_bucket_path.json).
* ``trace_ms``     — re-trace cost (jit cache miss): what every retrace
  (new batch shape, knob change) pays; the persistent plan's cached
  plan/world/tables are amortized here.
* ``collectives`` / ``critical_depth`` / ``link_bytes`` — structural
  metrics from the compiled HLO (hardware-independent; reduce_scatter's
  wire-byte story transfers to the TPU target even where CPU wall clock
  does not move).

Emits ``BENCH_bucket_path.json`` via :func:`benchmarks.common.emit_json`
with a summary comparing the seed cell (xla / all_reduce / per_step) to the
fast cell (pallas / all_reduce / persistent).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, SMOKE, block, emit_json, mesh_1d, time_fn
from repro.compat import shard_map
from repro.core import get_comm_plan, plan_cache_clear, plan_cache_stats, \
    reduce_gradients
from repro.launch.roofline import collective_critical_depth, parse_collectives


def grads_tree(arch: str, layers: int, seed: int = 0):
    """A gradient-shaped pytree: the arch's param shapes with the layer
    stack unstacked to ``layers`` per-layer leaves (DDP message regime)."""
    from repro.configs import get_config
    from repro.models.transformer import init_params

    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    rng = np.random.default_rng(seed)
    tree = {}

    def add(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if name.startswith("layers"):
            for i in range(layers):  # unstack (and synthesize depth)
                tree[f"{name}/{i}"] = jnp.asarray(
                    rng.normal(size=leaf.shape[1:]) * 1e-2, jnp.float32)
        else:
            tree[name] = jnp.asarray(
                rng.normal(size=leaf.shape) * 1e-2, jnp.float32)

    jax.tree_util.tree_map_with_path(add, struct)
    return tree


def make_step(mesh, tree, *, pack: str, reduction: str, persistent: bool,
              streams: int):
    spec_in = jax.tree_util.tree_map(lambda _: P(), tree)

    def run(tr):
        cp = get_comm_plan(tr, num_streams=streams, num_vcis=streams + 1,
                           pack=pack, token_impl="data",
                           persistent=persistent)
        rt = cp.runtime()
        red = reduce_gradients(rt, tr, cp, axis="data", mean=True,
                               pack=pack, reduction=reduction)
        return rt.barrier(red)

    return shard_map(run, mesh=mesh, in_specs=(spec_in,),
                     out_specs=spec_in, check_vma=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--arch", default="olmo-1b-smoke")
    ap.add_argument("--layers", type=int, default=8,
                    help="unstacked layer count (synthetic depth)")
    ap.add_argument("--trace-reps", type=int, default=4)
    args = ap.parse_args()

    mesh = mesh_1d(args.devices)
    tree = grads_tree(args.arch, args.layers)
    n_leaves = len(jax.tree_util.tree_leaves(tree))
    n_elems = sum(l.size for l in jax.tree_util.tree_leaves(tree))
    print(f"# grads: {n_leaves} leaves, {n_elems / 1e6:.2f}M f32 elements, "
          f"{args.streams} streams, {mesh.size} devices")

    csv = CSV("bucket_path")
    rows = []
    trace_reps = 2 if SMOKE else args.trace_reps
    for pack in ("xla", "pallas"):
        for reduction in ("all_reduce", "reduce_scatter"):
            for plan_mode in ("per_step", "persistent"):
                persistent = plan_mode == "persistent"
                plan_cache_clear()
                f = make_step(mesh, tree, pack=pack, reduction=reduction,
                              persistent=persistent, streams=args.streams)
                jf = jax.jit(f)
                hlo = jf.lower(tree).compile().as_text()
                jf(tree)  # warm
                t_jit = time_fn(lambda: block(jf(tree)), warmup=2, reps=10)
                # retrace cost (jit cache miss): fresh wrapper => full trace
                t_trace = time_fn(
                    lambda: jax.jit(lambda tr: f(tr)).lower(tree),
                    warmup=1, reps=trace_reps, min_time_s=0.0)
                d = collective_critical_depth(hlo)
                link_bytes = sum(op.link_bytes
                                 for op in parse_collectives(hlo, mesh.size))
                row = dict(pack=pack, reduction=reduction, plan=plan_mode,
                           ms_per_step=t_jit["median_s"] * 1e3,
                           ms_per_step_min=t_jit["min_s"] * 1e3,
                           trace_ms=t_trace["median_s"] * 1e3,
                           collectives=d["collective_count"],
                           critical_depth=d["critical_depth"],
                           link_bytes=link_bytes,
                           plan_cache=str(plan_cache_stats()))
                csv.add(**row)
                rows.append(row)
    csv.dump()

    def cell(pack, reduction, plan):
        return next(r for r in rows if r["pack"] == pack and
                    r["reduction"] == reduction and r["plan"] == plan)

    seed = cell("xla", "all_reduce", "per_step")
    fast = cell("pallas", "all_reduce", "persistent")
    best = min(rows, key=lambda r: r["ms_per_step"])
    summary = {
        "seed_config": {k: seed[k] for k in ("pack", "reduction", "plan")},
        "fast_config": {k: fast[k] for k in ("pack", "reduction", "plan")},
        "seed_ms_per_step": seed["ms_per_step"],
        "fast_ms_per_step": fast["ms_per_step"],
        "step_speedup": seed["ms_per_step"] / fast["ms_per_step"],
        "seed_trace_ms": seed["trace_ms"],
        "fast_trace_ms": fast["trace_ms"],
        "trace_speedup": seed["trace_ms"] / fast["trace_ms"],
        "best_config": {k: best[k] for k in ("pack", "reduction", "plan")},
        "best_ms_per_step": best["ms_per_step"],
    }
    print(f"# summary: seed {summary['seed_ms_per_step']:.2f} ms/step -> "
          f"fast {summary['fast_ms_per_step']:.2f} ms/step "
          f"({summary['step_speedup']:.2f}x step, "
          f"{summary['trace_speedup']:.2f}x retrace)")
    emit_json("bucket_path", {"rows": rows, "summary": summary})


if __name__ == "__main__":
    main()
