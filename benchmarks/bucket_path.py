"""Bucketed-gradient fast-path ablation — this repo's §4.3 analogue.

Three orthogonal knobs x the real ``reduce_gradients`` hot path on a
gradient-shaped pytree (a model parameter tree with the layer stack
unstacked into per-layer leaves — the DDP many-small-messages regime the
paper's message-rate story is about):

* ``plan``       per_step (seed: rebuild BucketPlan + CommWorld + contexts
                 inside every trace) vs persistent (``get_comm_plan`` cache
                 — the per-VCI request-cache analogue).
* ``pack``       xla (O(leaves) concat chain per bucket) vs pallas (the
                 tile/slot-aligned DMA layout: ``bucket_pack_pallas`` /
                 ``bucket_unpack_pallas`` tile-gather kernels on TPU,
                 per-slot dynamic_update_slice DMA writes off-TPU).
* ``reduction``  all_reduce vs reduce_scatter + all_gather per bucket vs
                 zero1 (ZeRO-1: reduce_scatter only — each rank's shard
                 feeds ``sharded_adamw_update`` directly and the *updated
                 params* are all-gathered in ``--zero1-wire`` dtype, bf16
                 by default, the mixed-precision deployment recipe). The
                 zero1 cells run the REAL sharded-optimizer cycle (scatter
                 -> local AdamW on m/v/master shards -> param gather), and
                 the summary reports ``zero1_wire_ratio`` against the
                 all_reduce cell — the paper-level claim that per-channel
                 payload reduction, not just channel count, sets
                 MPI+threads throughput.

Wire-byte accounting: ``link_bytes`` is parsed from the compiled HLO, but
XLA:CPU legalizes bf16 collectives by converting to f32 (bf16 is not native
on CPU), so on this emulation mesh the HLO column cannot see a narrow wire
dtype; TPU keeps bf16 collectives. ``wire_link_bytes`` therefore applies
the same ring model (all-reduce ``2(n-1)/n``, reduce-scatter / all-gather
``(n-1)/n``) to the payload dtype the program REQUESTED — the bytes a real
interconnect carries per step, param all_gather counted.

Reported per cell:

* ``ms_per_step``  — compiled steady-state wall clock per step (median).
  The headline: on the 8-device CPU mesh the concat-chain pack
  materializes a copy per operand and dominates the step, so the
  pallas/DMA layout roughly halves the step (see BENCH_bucket_path.json).
* ``trace_ms``     — re-trace cost (jit cache miss): what every retrace
  (new batch shape, knob change) pays; the persistent plan's cached
  plan/world/tables are amortized here.
* ``collectives`` / ``critical_depth`` / ``link_bytes`` — structural
  metrics from the compiled HLO (hardware-independent; reduce_scatter's
  wire-byte story transfers to the TPU target even where CPU wall clock
  does not move).

Emits ``BENCH_bucket_path.json`` via :func:`benchmarks.common.emit_json`
with a summary comparing the seed cell (xla / all_reduce / per_step) to the
fast cell (pallas / all_reduce / persistent).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, SMOKE, block, emit_json, mesh_1d, time_fn
from repro.compat import shard_map
from repro.core import TILE, get_comm_plan, plan_cache_clear, \
    plan_cache_stats, reduce_gradients
from repro.core.bucketing import ShardLayout, all_gather_shards, plan_buckets
from repro.dist.sharding import zero1_opt_specs
from repro.launch.roofline import collective_critical_depth, parse_collectives
from repro.optim.adamw import bucket_decay_masks, sharded_adamw_init, \
    sharded_adamw_update


def grads_tree(arch: str, layers: int, seed: int = 0):
    """A gradient-shaped pytree: the arch's param shapes with the layer
    stack unstacked to ``layers`` per-layer leaves (DDP message regime)."""
    from repro.configs import get_config
    from repro.models.transformer import init_params

    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    rng = np.random.default_rng(seed)
    tree = {}

    def add(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if name.startswith("layers"):
            for i in range(layers):  # unstack (and synthesize depth)
                tree[f"{name}/{i}"] = jnp.asarray(
                    rng.normal(size=leaf.shape[1:]) * 1e-2, jnp.float32)
        else:
            tree[name] = jnp.asarray(
                rng.normal(size=leaf.shape) * 1e-2, jnp.float32)

    jax.tree_util.tree_map_with_path(add, struct)
    return tree


def make_step(mesh, tree, *, pack: str, reduction: str, persistent: bool,
              streams: int):
    """(shard_mapped fn, example args) for one ablation cell."""
    spec_in = jax.tree_util.tree_map(lambda _: P(), tree)

    def run(tr):
        cp = get_comm_plan(tr, num_streams=streams, num_vcis=streams + 1,
                           pack=pack, token_impl="data",
                           persistent=persistent)
        rt = cp.runtime()
        red = reduce_gradients(rt, tr, cp, axis="data", mean=True,
                               pack=pack, reduction=reduction)
        return rt.barrier(red)

    f = shard_map(run, mesh=mesh, in_specs=(spec_in,),
                  out_specs=spec_in, check_vma=False)
    return f, (tree,)


def make_step_zero1(mesh, tree, *, pack: str, persistent: bool, streams: int,
                    wire):
    """The full ZeRO-1 cycle as one step: grad reduce_scatter (wire dtype)
    -> sharded AdamW on the local m/v/master shards -> updated-param
    all_gather (wire dtype) on the same per-bucket contexts."""
    spec_in = jax.tree_util.tree_map(lambda _: P(), tree)
    slot_align = TILE if pack == "pallas" else None
    plan = plan_buckets(tree, streams, align=TILE, slot_align=slot_align)
    ShardLayout(plan, mesh.size)  # validate divisibility up front
    state = sharded_adamw_init(tree, plan)
    spec_state = zero1_opt_specs(mesh, state)
    masks = tuple(jnp.asarray(m) for m in bucket_decay_masks(plan))

    def run(tr, st, mask_shards):
        cp = get_comm_plan(tr, num_streams=streams, num_vcis=streams + 1,
                           pack=pack, token_impl="data",
                           persistent=persistent)
        rt = cp.runtime()
        shards, layout = reduce_gradients(
            rt, tr, cp, axis="data", mean=True, pack=pack,
            reduction="reduce_scatter", output="shards", reduce_dtype=wire)
        new_shards, new_st, _ = sharded_adamw_update(
            shards, st, lr=jnp.float32(1e-3), layout=layout,
            decay_masks=mask_shards,
            psum=lambda s: rt.all_reduce(s, cp.contexts[0], axis="data"))
        params = all_gather_shards(rt, new_shards, cp, axis="data",
                                   wire_dtype=wire)
        return rt.barrier((params, new_st))

    f = shard_map(run, mesh=mesh,
                  in_specs=(spec_in, spec_state,
                            tuple(P("data") for _ in masks)),
                  out_specs=(spec_in, spec_state), check_vma=False)
    return f, (tree, state, masks)


def wire_model_bytes(tree, *, streams: int, n: int, reduction: str,
                     pack: str, wire_bytes: int = 4) -> float:
    """Ring-model per-chip wire bytes for one reduction step, using the
    REQUESTED payload dtypes (see module docstring: XLA:CPU promotes bf16
    collectives to f32, so the HLO-parsed column under-reports the dtype
    saving that TPU interconnects realize)."""
    slot_align = TILE if pack == "pallas" else None
    plan = plan_buckets(tree, streams, align=TILE, slot_align=slot_align)
    tot = plan.total_padded
    ring = (n - 1) / n
    if reduction == "all_reduce":
        return 2 * ring * tot * 4                      # f32 grad all-reduce
    if reduction == "reduce_scatter":
        return ring * tot * 4 * 2                      # f32 grad rs + grad ag
    # zero1: grad rs + PARAM ag, both in wire dtype, + the scalar norm psum
    return ring * tot * wire_bytes * 2 + 2 * ring * 4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--zero1-wire", default="bfloat16",
                    help="wire dtype of the zero1 cells' grad scatter + "
                         "param gather (fp32 master shards absorb the "
                         "rounding)")
    ap.add_argument("--arch", default="olmo-1b-smoke")
    ap.add_argument("--layers", type=int, default=8,
                    help="unstacked layer count (synthetic depth)")
    ap.add_argument("--trace-reps", type=int, default=4)
    args = ap.parse_args()

    mesh = mesh_1d(args.devices)
    tree = grads_tree(args.arch, args.layers)
    n_leaves = len(jax.tree_util.tree_leaves(tree))
    n_elems = sum(l.size for l in jax.tree_util.tree_leaves(tree))
    print(f"# grads: {n_leaves} leaves, {n_elems / 1e6:.2f}M f32 elements, "
          f"{args.streams} streams, {mesh.size} devices")

    csv = CSV("bucket_path")
    rows = []
    trace_reps = 2 if SMOKE else args.trace_reps
    wire = jnp.dtype(args.zero1_wire)
    for pack in ("xla", "pallas"):
        for reduction in ("all_reduce", "reduce_scatter", "zero1"):
            for plan_mode in ("per_step", "persistent"):
                persistent = plan_mode == "persistent"
                plan_cache_clear()
                if reduction == "zero1":
                    f, fargs = make_step_zero1(
                        mesh, tree, pack=pack, persistent=persistent,
                        streams=args.streams, wire=wire)
                else:
                    f, fargs = make_step(
                        mesh, tree, pack=pack, reduction=reduction,
                        persistent=persistent, streams=args.streams)
                jf = jax.jit(f)
                hlo = jf.lower(*fargs).compile().as_text()
                jf(*fargs)  # warm
                t_jit = time_fn(lambda: block(jf(*fargs)), warmup=2, reps=10)
                # retrace cost (jit cache miss): fresh wrapper => full trace
                t_trace = time_fn(
                    lambda: jax.jit(lambda *a: f(*a)).lower(*fargs),
                    warmup=1, reps=trace_reps, min_time_s=0.0)
                d = collective_critical_depth(hlo)
                link_bytes = sum(op.link_bytes
                                 for op in parse_collectives(hlo, mesh.size))
                row = dict(pack=pack, reduction=reduction, plan=plan_mode,
                           ms_per_step=t_jit["median_s"] * 1e3,
                           ms_per_step_min=t_jit["min_s"] * 1e3,
                           trace_ms=t_trace["median_s"] * 1e3,
                           collectives=d["collective_count"],
                           critical_depth=d["critical_depth"],
                           link_bytes=link_bytes,
                           wire_link_bytes=wire_model_bytes(
                               tree, streams=args.streams, n=mesh.size,
                               reduction=reduction, pack=pack,
                               wire_bytes=wire.itemsize),
                           plan_cache=str(plan_cache_stats()))
                csv.add(**row)
                rows.append(row)
    csv.dump()

    def cell(pack, reduction, plan):
        return next(r for r in rows if r["pack"] == pack and
                    r["reduction"] == reduction and r["plan"] == plan)

    seed = cell("xla", "all_reduce", "per_step")
    fast = cell("pallas", "all_reduce", "persistent")
    ar = fast  # doubles as the f32 all_reduce baseline for the wire ratio
    z1 = cell("pallas", "zero1", "persistent")
    best = min(rows, key=lambda r: r["ms_per_step"])
    summary = {
        "seed_config": {k: seed[k] for k in ("pack", "reduction", "plan")},
        "fast_config": {k: fast[k] for k in ("pack", "reduction", "plan")},
        "seed_ms_per_step": seed["ms_per_step"],
        "fast_ms_per_step": fast["ms_per_step"],
        "step_speedup": seed["ms_per_step"] / fast["ms_per_step"],
        "seed_trace_ms": seed["trace_ms"],
        "fast_trace_ms": fast["trace_ms"],
        "trace_speedup": seed["trace_ms"] / fast["trace_ms"],
        "best_config": {k: best[k] for k in ("pack", "reduction", "plan")},
        "best_ms_per_step": best["ms_per_step"],
        # ZeRO-1 wire-byte story: grad reduce_scatter + PARAM all_gather
        # (both counted, --zero1-wire dtype) vs the f32 grad all_reduce,
        # ring model at the requested dtypes (wire_link_bytes column; the
        # HLO-parsed link_bytes shows f32 on CPU, which promotes bf16
        # collectives).
        "zero1_wire_dtype": str(wire),
        "zero1_wire_link_bytes": z1["wire_link_bytes"],
        "all_reduce_wire_link_bytes": ar["wire_link_bytes"],
        "zero1_wire_ratio": (z1["wire_link_bytes"]
                             / max(ar["wire_link_bytes"], 1)),
    }
    print(f"# summary: seed {summary['seed_ms_per_step']:.2f} ms/step -> "
          f"fast {summary['fast_ms_per_step']:.2f} ms/step "
          f"({summary['step_speedup']:.2f}x step, "
          f"{summary['trace_speedup']:.2f}x retrace)")
    print(f"# zero1 wire bytes ({summary['zero1_wire_dtype']} wire, param "
          f"all_gather counted): {z1['wire_link_bytes']/1e6:.2f} MB vs "
          f"all_reduce {ar['wire_link_bytes']/1e6:.2f} MB -> "
          f"{summary['zero1_wire_ratio']:.2f}x per step")
    emit_json("bucket_path", {"rows": rows, "summary": summary})


if __name__ == "__main__":
    main()
