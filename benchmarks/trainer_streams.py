"""Trainer-level VCI stream scaling — the paper's message-rate claim
exercised through the REAL training API (not a microbenchmark).

``make_train_step(comm="vci", num_streams=K, progress=...)`` buckets the
gradient pytree onto K CommContexts; this sweeps K and the progress model
and reports the compiled step's collective structure + wall clock. The
paper's story at this level: serialized streams (global progress) keep
K chained reductions; independent streams let XLA combine/overlap them.

The fast-path knobs ride along: ``--pack``/``--reduction``/``--per-step-plan``
select the bucketed-reduction implementation (see ``benchmarks.bucket_path``
for the dedicated 3-knob ablation of that hot path), and ``--optimizer
zero1`` swaps in the ZeRO-1 sharded AdamW (reduce_scatter shards consumed
directly, updated params all-gathered — half the gradient wire bytes).
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import CSV, SMOKE, block, mesh_1d, time_fn
from repro.compat import set_mesh
from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.launch.roofline import collective_critical_depth
from repro.train.trainer import make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--pack", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--reduction", default="all_reduce",
                    choices=("all_reduce", "reduce_scatter"))
    ap.add_argument("--per-step-plan", action="store_true",
                    help="seed behaviour: rebuild the comm plan every trace")
    ap.add_argument("--optimizer", default="replicated",
                    choices=("replicated", "zero1"),
                    help="zero1 = ZeRO-1 sharded AdamW (reduce_scatter "
                         "shards in, updated-param all_gather out)")
    ap.add_argument("--zero1-wire", default=None,
                    help="zero1 wire dtype (e.g. bfloat16); default f32")
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)
    cfg = get_config("olmo-1b-smoke")
    batch = synthetic_batch(cfg, 2 * mesh.size, 32, seed=0)

    progresses = ("hybrid",) if SMOKE else ("global", "hybrid", "per_vci")
    stream_counts = (1, 4) if SMOKE else (1, 2, 4, 8)

    csv = CSV("trainer_vci_streams")
    for progress in progresses:
        for streams in stream_counts:
            state = train_state_init(cfg, jax.random.PRNGKey(0),
                                     optimizer=args.optimizer, mesh=mesh,
                                     num_streams=streams, pack=args.pack)
            step = make_train_step(cfg, mesh=mesh, comm="vci",
                                   num_streams=streams,
                                   num_vcis=streams + 1,
                                   progress=progress, token_impl="data",
                                   pack=args.pack, reduction=args.reduction,
                                   persistent_plan=not args.per_step_plan,
                                   optimizer=args.optimizer,
                                   zero1_wire_dtype=args.zero1_wire)
            with set_mesh(mesh):
                jitted = jax.jit(step)
                compiled = jitted.lower(state, batch).compile()
                hlo = compiled.as_text()
                jitted(state, batch)
                t = time_fn(lambda: block(jitted(state, batch)), reps=5)
            d = collective_critical_depth(hlo)
            csv.add(progress=progress, streams=streams, pack=args.pack,
                    reduction=args.reduction, optimizer=args.optimizer,
                    ms_per_step=t["median_s"] * 1e3,
                    collectives=d["collective_count"],
                    critical_depth=d["critical_depth"])
    csv.dump()


if __name__ == "__main__":
    main()
