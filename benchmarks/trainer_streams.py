"""Trainer-level VCI stream scaling — the paper's message-rate claim
exercised through the REAL training API (not a microbenchmark).

``make_train_step(comm="vci", num_streams=K, progress=...)`` buckets the
gradient pytree onto K CommContexts; this sweeps K and the progress model
and reports the compiled step's collective structure + wall clock. The
paper's story at this level: serialized streams (global progress) keep
K chained reductions; independent streams let XLA combine/overlap them.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.launch.roofline import collective_critical_depth
from repro.train.trainer import make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)
    cfg = get_config("olmo-1b-smoke")
    batch = synthetic_batch(cfg, 2 * mesh.size, 32, seed=0)
    state = train_state_init(cfg, jax.random.PRNGKey(0))

    csv = CSV("trainer_vci_streams")
    for progress in ("global", "hybrid", "per_vci"):
        for streams in (1, 2, 4, 8):
            step = make_train_step(cfg, mesh=mesh, comm="vci",
                                   num_streams=streams,
                                   num_vcis=streams + 1,
                                   progress=progress, token_impl="data")
            with jax.set_mesh(mesh):
                jitted = jax.jit(step)
                compiled = jitted.lower(state, batch).compile()
                hlo = compiled.as_text()
                jitted(state, batch)
                t = time_fn(lambda: block(jitted(state, batch)), reps=5)
            d = collective_critical_depth(hlo)
            csv.add(progress=progress, streams=streams,
                    ms_per_step=t["median_s"] * 1e3,
                    collectives=d["collective_count"],
                    critical_depth=d["critical_depth"])
    csv.dump()


if __name__ == "__main__":
    main()
