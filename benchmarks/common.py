"""Shared benchmark infrastructure.

Benchmarks run in SUBPROCESSES spawned by ``benchmarks.run``: each gets its
own ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so the parent
process (and pytest) keep the single real CPU device. Wall-clock numbers on
CPU host devices are *proxies* — the paper's OPA/IB NICs are not present —
so every benchmark also reports structural metrics (token-dependency counts,
HLO collective chains) that transfer to the TPU target, and EXPERIMENTS.md
validates *directionality and ratio ordering*, not absolute microseconds.

CPU-specific choice: ordering tokens use ``token_impl="data"`` — XLA:CPU
elides optimization-barrier before scheduling, which would erase the very
serialization being measured. The "data" tokens thread the dependency
through payload arithmetic (numerically a no-op), which no backend can
remove. On TPU the zero-copy "barrier" impl is the default.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# BENCH_SMOKE=1 (set by ``benchmarks.run --smoke``) clamps every timing loop
# to 2 iterations so the perf code paths execute end-to-end under pytest
# without paying for statistically meaningful medians.
SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0") or "0"))


def mesh_1d(n: Optional[int] = None, name: str = "data"):
    from jax.sharding import Mesh
    devs = jax.devices()
    n = n or len(devs)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run via benchmarks.run "
            f"(it sets XLA_FLAGS) or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return Mesh(np.array(devs[:n]), (name,))


def time_fn(fn: Callable[[], object], *, warmup: int = 3, reps: int = 10,
            min_time_s: float = 0.2) -> Dict[str, float]:
    """Median wall-time of ``fn()`` (which must block until done)."""
    if SMOKE:
        warmup, reps, min_time_s = 1, 2, 0.0
    for _ in range(warmup):
        fn()
    times: List[float] = []
    t_total = 0.0
    r = 0
    while r < reps or t_total < min_time_s:
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        r += 1
        if r > 200:
            break
    arr = np.array(times)
    return {"median_s": float(np.median(arr)), "mean_s": float(arr.mean()),
            "min_s": float(arr.min()), "reps": len(arr)}


def block(tree):
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, tree)


class CSV:
    """Tiny CSV emitter: header from the first row's keys."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []

    def add(self, **row):
        self.rows.append(row)

    def dump(self, fh=None) -> str:
        import sys
        fh = fh or sys.stdout
        if not self.rows:
            return ""
        cols = list(self.rows[0].keys())
        lines = [",".join(cols)]
        for r in self.rows:
            lines.append(",".join(_fmt(r.get(c)) for c in cols))
        out = "\n".join(lines)
        print(f"# benchmark: {self.name}", file=fh)
        print(out, file=fh, flush=True)
        return out


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def emit_json(name: str, payload: Dict, out_dir: Optional[str] = None) -> str:
    """Write ``BENCH_<name>.json`` (repo root by default) and return the path.

    The JSON artifacts are the machine-readable counterpart of the CSV
    stdout streams: ``{"benchmark": ..., "env": {...}, **payload}``.
    ``BENCH_JSON_DIR`` redirects the output (pytest smoke runs use a tmp
    dir so the committed artifacts keep their full-run numbers).
    """
    out_dir = out_dir or os.environ.get("BENCH_JSON_DIR") or REPO
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    doc = {
        "benchmark": name,
        "env": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "jax": jax.__version__,
            "smoke": SMOKE,
        },
    }
    doc.update(payload)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    return path
