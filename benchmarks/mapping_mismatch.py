"""VCI-mapping mismatch — paper Fig. 17.

16 streams of user-exposed parallelism against pool sizes 1..16: with fewer
VCIs than streams, FCFS assignment collides contexts onto the fallback VCI
and serializes them even though the USER did everything right. The
``hinted`` policy (the paper's §5.2 suggestion) and explicit endpoint
pinning are shown as the remedies.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.launch.roofline import collective_critical_depth
from repro.compat import shard_map

N_STREAMS = 16
OPS = 8


def build(pool_size: int, mesh, *, policy="fcfs", pin=False):
    n = mesh.size
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(x):
        world = CommWorld(num_vcis=pool_size, policy=policy)
        rt = CommRuntime(world, progress="hybrid", join_every=4 * N_STREAMS,
                         token_impl="data")
        ctxs = []
        for s in range(N_STREAMS):
            if pin:
                ctxs.append(world.create(f"c{s}", vci=s % pool_size))
            else:
                hint = "dedicated" if policy == "hinted" else None
                ctxs.append(world.create(f"c{s}", hint=hint))
        outs = []
        for s in range(N_STREAMS):
            v = x[s]
            for _ in range(OPS):
                v = rt.sendrecv(v, ctxs[s], axis="data", perm=perm)
            outs.append(v)
        return rt.barrier(jnp.stack(outs))

    f = jax.jit(shard_map(step, mesh=mesh, in_specs=P(None, None),
                          out_specs=P(None, None), check_vma=False))
    return f


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)
    csv = CSV("mapping_mismatch")
    x = jnp.ones((N_STREAMS, 64), jnp.float32)
    for pool in (1, 2, 4, 8, 16, 17):
        for policy, pin in (("fcfs", False), ("hinted", False),
                            ("fcfs", True)):
            label = "endpoints(pinned)" if pin else policy
            f = build(pool, mesh, policy=policy, pin=pin)
            hlo = f.lower(x).compile().as_text()
            f(x)
            t = time_fn(lambda: block(f(x)))
            d = collective_critical_depth(hlo)
            csv.add(pool_size=pool, policy=label,
                    us_per_step=t["median_s"] * 1e6,
                    msgs_per_s=N_STREAMS * OPS * mesh.size / t["median_s"],
                    critical_depth=d["critical_depth"],
                    parallelism=round(d["parallelism"], 3))
    csv.dump()


if __name__ == "__main__":
    main()
