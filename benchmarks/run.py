"""Benchmark harness: one benchmark per paper table/figure.

Each benchmark runs in a SUBPROCESS with its own virtual-device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) so this parent
process never locks a multi-device CPU topology. Results (CSV) stream to
stdout and are archived under reports/bench/.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only message_rate
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (module, extra args, devices, paper figure)
BENCHMARKS = [
    ("benchmarks.overhead", [], 8, "Figs 2/3 (FG vs Global) + Fig 4 (setup)"),
    ("benchmarks.message_rate", [], 8, "Figs 10/11 (Isend rate)"),
    ("benchmarks.message_rate", ["--rma"], 8, "Figs 13/14 (Put rate)"),
    ("benchmarks.message_rate", ["--no-token", "--streams", "16",
                                 "--sizes", "2"], 8,
     "Fig 12 (no locks/atomics)"),
    ("benchmarks.progress_ablation", [], 8, "Figs 5-8 + Fig 19 ablations"),
    ("benchmarks.mapping_mismatch", [], 8, "Fig 17 (pool exhaustion)"),
    ("benchmarks.stencil", [], 16, "Fig 22 (stencil halo)"),
    ("benchmarks.ebms", [], 8, "Figs 24/25 (EBMS fetch)"),
    ("benchmarks.bspmm", [], 8, "Fig 27 (BSPMM accumulate)"),
    ("benchmarks.trainer_streams", [], 8,
     "paper claim at the trainer API level (VCI grad streams)"),
    ("benchmarks.trainer_streams", ["--optimizer", "zero1"], 8,
     "ZeRO-1 sharded AdamW on the VCI streams (scatter + param gather)"),
    ("benchmarks.bucket_path", [], 8,
     "fast bucketed-reduction path: plan x pack x reduction(+zero1) ablation"),
    ("benchmarks.overlap_schedule", [], 8,
     "bucket-ready overlap: exposed-comm vs schedule x num_vcis x optimizer "
     "(training-side Fig 17: same wire bytes, lower critical path)"),
    ("benchmarks.serve_streams", [], 8,
     "serve-path VCI streams: decode tok/s vs pool size (Fig 4/17 at the "
     "serving API level)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on the module name")
    ap.add_argument("--smoke", action="store_true",
                    help="2-iteration timing loops (BENCH_SMOKE=1): executes "
                         "every perf path end-to-end without full medians — "
                         "the mode the test suite runs under pytest")
    ap.add_argument("--out", default=os.path.join(REPO, "reports", "bench"))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for mod, extra, devices, figure in BENCHMARKS:
        if args.only and args.only not in mod + " ".join(extra):
            continue
        tag = mod.split(".")[-1] + ("_" + "_".join(
            a.strip("-") for a in extra) if extra else "")
        print(f"\n=== {tag}  [{figure}]  ({devices} devices) ===", flush=True)
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        if args.smoke:
            env["BENCH_SMOKE"] = "1"
        env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        t0 = time.time()
        r = subprocess.run(
            [sys.executable, "-m", mod, "--devices", str(devices), *extra],
            capture_output=True, text=True, cwd=REPO, env=env, timeout=3600)
        dur = time.time() - t0
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            failures += 1
            print(f"[FAIL] {tag} rc={r.returncode}\n{r.stderr[-2000:]}",
                  flush=True)
        else:
            print(f"[ok] {tag} in {dur:.0f}s", flush=True)
            with open(os.path.join(args.out, tag + ".csv"), "w") as f:
                f.write(r.stdout)
    print(f"\nbenchmarks done; failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
