"""Stencil halo exchange — paper §6.1, Fig. 22 (category 1: dedicated
channels suffice).

2D 5-point stencil on a (R x C) device grid. Each device owns a sub-block;
per iteration it exchanges N/S/E/W halos with its neighbours. MPI+threads
modes map halo directions x edge-threads onto communication streams:

  funneled     MPI_THREAD_FUNNELED: ONE stream for everything
  ser_comm     all four directions on one context (MULTIPLE but unexposed)
  par_comm     the paper's odd/even communicator sets: one context per
               direction per parity -> fully independent streams
  endpoints    one pinned VCI per direction (user-visible endpoints)
  everywhere   no tokens (MPI everywhere baseline)

The paper's result: par_comm+VCIs == endpoints == everywhere. The halo
pattern is pure neighbour ppermute, so the structural depth shows exactly
whether the four directions overlap.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from benchmarks.common import CSV, block, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.launch.roofline import collective_critical_depth
from repro.compat import shard_map


def grid_mesh(rows, cols):
    devs = jax.devices()
    assert len(devs) >= rows * cols
    return Mesh(np.array(devs[: rows * cols]).reshape(rows, cols), ("y", "x"))


def _perms(rows, cols):
    """Neighbour permutations on the flattened (y,x) grid per direction."""
    def at(r, c):
        return r * cols + c
    north = [(at(r, c), at((r - 1) % rows, c))
             for r in range(rows) for c in range(cols)]
    south = [(at(r, c), at((r + 1) % rows, c))
             for r in range(rows) for c in range(cols)]
    west = [(at(r, c), at(r, (c - 1) % cols))
            for r in range(rows) for c in range(cols)]
    east = [(at(r, c), at(r, (c + 1) % cols))
            for r in range(rows) for c in range(cols)]
    return {"n": north, "s": south, "w": west, "e": east}


def build(mode: str, rows, cols, block_size: int, mesh):
    perms = _perms(rows, cols)
    axis = ("y", "x")

    def halo_exchange(u):
        # u: local block (B, B). Halos: first/last rows/cols.
        halos = {
            "n": u[:1, :], "s": u[-1:, :], "w": u[:, :1], "e": u[:, -1:],
        }
        if mode == "everywhere":
            recv = {d: jax.lax.ppermute(h, axis, perms[d])
                    for d, h in halos.items()}
            rt = None
        else:
            if mode == "funneled" or mode == "ser_comm":
                world = CommWorld(num_vcis=1 if mode == "funneled" else 8)
                rt = CommRuntime(world, progress="global" if mode == "funneled"
                                 else "hybrid", token_impl="data")
                ctx = world.create("halo")
                ctxs = {d: ctx for d in halos}
            elif mode == "par_comm":
                # odd/even sets: direction-parity -> independent contexts.
                # On the device grid the parity trick collapses to one
                # context per direction (threads on an edge share nothing).
                world = CommWorld(num_vcis=8)
                rt = CommRuntime(world, progress="hybrid", join_every=16,
                                 token_impl="data")
                ctxs = {d: world.create(f"halo_{d}") for d in halos}
            elif mode == "endpoints":
                world = CommWorld(num_vcis=8)
                rt = CommRuntime(world, progress="per_vci", token_impl="data")
                ctxs = {d: world.create(f"ep_{d}", vci=i + 1)
                        for i, d in enumerate(halos)}
            else:
                raise ValueError(mode)
            recv = {d: rt.sendrecv(h, ctxs[d], axis=axis, perm=perms[d])
                    for d, h in halos.items()}

        # 5-point update using the received halos
        up = jnp.concatenate([recv["s"], u[:-1, :]], axis=0)
        dn = jnp.concatenate([u[1:, :], recv["n"]], axis=0)
        lf = jnp.concatenate([recv["e"], u[:, :-1]], axis=1)
        rg = jnp.concatenate([u[:, 1:], recv["w"]], axis=1)
        out = 0.25 * (up + dn + lf + rg)
        return rt.barrier(out) if rt is not None else out

    f = jax.jit(shard_map(halo_exchange, mesh=mesh,
                          in_specs=P("y", "x"), out_specs=P("y", "x"),
                          check_vma=False))
    u = jnp.ones((rows * block_size, cols * block_size), jnp.float32)
    return f, u


MODES = ["everywhere", "funneled", "ser_comm", "par_comm", "endpoints"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--cols", type=int, default=4)
    args = ap.parse_args()
    rows, cols = args.rows, args.cols
    mesh = grid_mesh(rows, cols)
    csv = CSV("stencil_halo")
    for bs in (64, 256, 1024):   # mesh sizes (local block edge)
        for mode in MODES:
            f, u = build(mode, rows, cols, bs, mesh)
            hlo = f.lower(u).compile().as_text()
            f(u)
            t = time_fn(lambda: block(f(u)))
            d = collective_critical_depth(hlo)
            csv.add(mode=mode, block=bs, us_per_iter=t["median_s"] * 1e6,
                    critical_depth=d["critical_depth"],
                    parallelism=round(d["parallelism"], 3))
    csv.dump()


if __name__ == "__main__":
    main()
