"""Message-rate microbenchmark — paper Figs. 10, 11, 12, 13, 14.

Aggregate rate at which parallel "threads" (streams) inject small messages.
Each stream issues OPS_PER_STREAM point-to-point messages (ppermute pairs,
the Isend/Irecv analogue) or RMA Puts per step. Execution modes mirror §5:

  everywhere        no thread-safety tokens at all, one stream per "core"
                    (MPI everywhere: private library state per process)
  ser_comm+orig     ONE context, global critical section (original MPICH)
  ser_comm+vcis     ONE context on the multi-VCI library (no exposed
                    parallelism -> 1 VCI; optimizations can't help)
  par_comm+orig     N contexts but a single global lock (original MPICH
                    given user-exposed parallelism)
  par_comm+vcis     N contexts -> N VCIs, hybrid progress (this paper)
  endpoints         N contexts with explicitly pinned VCIs, pure per-VCI
                    progress (the user-visible-endpoints upper bound)

Reported: million messages/s (aggregate) + the token-dependency depth
(structural serialization, hardware-independent).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, SMOKE, block, mesh_1d, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.compat import shard_map

OPS_PER_STREAM = 16


def _issue(rt, v, ctx, *, collective: str, rma: bool, perm, n: int):
    """One message on ``ctx``'s stream: the p2p/RMA pair of the original
    figures, or the bucketed-reduction fast path's collectives
    (``all_reduce`` vs ``reduce_scatter``+``all_gather``) so the per-message
    software overhead of the gradient hot path is measured with the same
    stream/token machinery. Reductions are normalized by ``n`` (mean) so
    chained ops keep O(1) values — without it the 16-deep chain grows n^16
    and overflows f32 at high device counts — and so every mode (including
    the token-free ``everywhere`` baseline) runs the same program."""
    if collective == "all_reduce":
        return rt.all_reduce(v, ctx, axis="data") / n
    if collective == "reduce_scatter":
        shard = rt.reduce_scatter(v, ctx, axis="data") / n
        return rt.all_gather(shard, ctx, axis="data")
    if rma:
        return rt.put(v, ctx, axis="data", perm=perm)
    return rt.sendrecv(v, ctx, axis="data", perm=perm)


def build_step(mode: str, n_streams: int, msg_elems: int, *, rma: bool,
               mesh, no_token: bool = False, collective: str = "sendrecv"):
    """Returns a jitted step issuing n_streams x OPS_PER_STREAM messages."""
    n = mesh.size
    perm = [(i, (i + 1) % n) for i in range(n)]
    kind = "rma" if rma else "p2p"

    def step(x):  # x: per-shard (n_streams, msg_elems)
        if mode == "everywhere" or no_token:
            # private library state per core: no tokens at all
            outs = []
            for s in range(n_streams):
                v = x[s]
                for _ in range(OPS_PER_STREAM):
                    if collective == "all_reduce":
                        v = jax.lax.psum(v, "data") / n
                    elif collective == "reduce_scatter":
                        v = jax.lax.all_gather(
                            jax.lax.psum_scatter(v, "data", tiled=True) / n,
                            "data", tiled=True)
                    else:
                        v = jax.lax.ppermute(v, "data", perm)
                outs.append(v)
            return jnp.stack(outs)

        if mode == "ser_comm+orig":
            world = CommWorld(num_vcis=1)
            rt = CommRuntime(world, progress="global", token_impl="data")
            shared = world.create("c0", kind=kind)
            ctxs = [shared] * n_streams
        elif mode == "ser_comm+vcis":
            world = CommWorld(num_vcis=max(n_streams, 1))
            rt = CommRuntime(world, progress="hybrid", token_impl="data")
            shared = world.create("c0", kind=kind)
            ctxs = [shared] * n_streams
        elif mode == "par_comm+orig":
            world = CommWorld(num_vcis=1)
            rt = CommRuntime(world, progress="global", token_impl="data")
            ctxs = [world.create(f"c{s}", kind=kind) for s in range(n_streams)]
        elif mode == "par_comm+vcis":
            world = CommWorld(num_vcis=n_streams + 1)
            rt = CommRuntime(world, progress="hybrid",
                             join_every=4 * n_streams, token_impl="data")
            ctxs = [world.create(f"c{s}", kind=kind) for s in range(n_streams)]
        elif mode == "endpoints":
            world = CommWorld(num_vcis=n_streams + 1)
            rt = CommRuntime(world, progress="per_vci", token_impl="data")
            ctxs = [world.create(f"c{s}", kind=kind, vci=(s % world.pool.num_vcis))
                    for s in range(n_streams)]
        else:
            raise ValueError(mode)

        outs = []
        for s in range(n_streams):
            v = x[s]
            for _ in range(OPS_PER_STREAM):
                v = _issue(rt, v, ctxs[s], collective=collective, rma=rma,
                           perm=perm, n=n)
            outs.append(v)
        return rt.barrier(jnp.stack(outs))

    f = jax.jit(shard_map(step, mesh=mesh, in_specs=P(None, None),
                          out_specs=P(None, None), check_vma=False))
    x = jnp.ones((n_streams, msg_elems), jnp.float32)
    hlo = f.lower(x).compile().as_text()
    f(x)  # warm
    return f, x, hlo


MODES = ["everywhere", "ser_comm+orig", "ser_comm+vcis", "par_comm+orig",
         "par_comm+vcis", "endpoints"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rma", action="store_true", help="MPI_Put (Figs 13/14)")
    ap.add_argument("--no-token", action="store_true",
                    help="Fig 12: disable locking/atomics analogue")
    ap.add_argument("--collective", default="sendrecv",
                    choices=("sendrecv", "all_reduce", "reduce_scatter"),
                    help="per-stream message type: the p2p pair of the "
                         "original figures, or the gradient fast path's "
                         "all_reduce vs reduce_scatter+all_gather")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--sizes", type=int, nargs="*",
                    default=[2, 512, 8192])   # 8B .. 32KB messages
    ap.add_argument("--streams", type=int, nargs="*",
                    default=[1, 2, 4, 8, 16])
    args = ap.parse_args()

    mesh = mesh_1d(args.devices)
    if SMOKE:
        args.sizes = args.sizes[:1]
        args.streams = [s for s in args.streams if s in (1, max(args.streams))]
    if args.collective == "reduce_scatter":
        # psum_scatter needs the message length to divide the axis size
        args.sizes = [-(-m // mesh.size) * mesh.size for m in args.sizes]
    name = "message_rate" + ("_rma" if args.rma else "")
    csv = CSV(name)

    from repro.launch.roofline import collective_critical_depth

    for msg in args.sizes:
        for ns in args.streams:
            for mode in MODES:
                f, x, hlo = build_step(mode, ns, msg, rma=args.rma, mesh=mesh,
                                       no_token=args.no_token and
                                       mode == "par_comm+vcis",
                                       collective=args.collective)
                t = time_fn(lambda: block(f(x)))
                n_msgs = ns * OPS_PER_STREAM * mesh.size
                d = collective_critical_depth(hlo)
                # projected rate on a parallel network: depth is the serial
                # bottleneck, so rate scales with ops/depth (the structural
                # analogue of the paper's thread-scaling curves)
                csv.add(mode=mode, collective=args.collective, streams=ns,
                        msg_bytes=msg * 4,
                        mmsgs_per_s=n_msgs / t["median_s"] / 1e6,
                        us_per_step=t["median_s"] * 1e6,
                        critical_depth=d["critical_depth"],
                        parallelism=round(d["parallelism"], 3))
    csv.dump()


if __name__ == "__main__":
    main()
