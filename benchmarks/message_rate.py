"""Message-rate microbenchmark — paper Figs. 10, 11, 12, 13, 14.

Aggregate rate at which parallel "threads" (streams) inject small messages.
Each stream issues OPS_PER_STREAM point-to-point messages (ppermute pairs,
the Isend/Irecv analogue) or RMA Puts per step. Execution modes mirror §5:

  everywhere        no thread-safety tokens at all, one stream per "core"
                    (MPI everywhere: private library state per process)
  ser_comm+orig     ONE context, global critical section (original MPICH)
  ser_comm+vcis     ONE context on the multi-VCI library (no exposed
                    parallelism -> 1 VCI; optimizations can't help)
  par_comm+orig     N contexts but a single global lock (original MPICH
                    given user-exposed parallelism)
  par_comm+vcis     N contexts -> N VCIs, hybrid progress (this paper)
  endpoints         N contexts with explicitly pinned VCIs, pure per-VCI
                    progress (the user-visible-endpoints upper bound)

Reported: million messages/s (aggregate) + the token-dependency depth
(structural serialization, hardware-independent).
"""

from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld

OPS_PER_STREAM = 16


def build_step(mode: str, n_streams: int, msg_elems: int, *, rma: bool,
               mesh, no_token: bool = False):
    """Returns a jitted step issuing n_streams x OPS_PER_STREAM messages."""
    n = mesh.size
    perm = [(i, (i + 1) % n) for i in range(n)]
    kind = "rma" if rma else "p2p"

    def step(x):  # x: per-shard (n_streams, msg_elems)
        if mode == "everywhere" or no_token:
            # private library state per core: no tokens at all
            outs = []
            for s in range(n_streams):
                v = x[s]
                for _ in range(OPS_PER_STREAM):
                    v = jax.lax.ppermute(v, "data", perm)
                outs.append(v)
            return jnp.stack(outs)

        if mode == "ser_comm+orig":
            world = CommWorld(num_vcis=1)
            rt = CommRuntime(world, progress="global", token_impl="data")
            shared = world.create("c0", kind=kind)
            ctxs = [shared] * n_streams
        elif mode == "ser_comm+vcis":
            world = CommWorld(num_vcis=max(n_streams, 1))
            rt = CommRuntime(world, progress="hybrid", token_impl="data")
            shared = world.create("c0", kind=kind)
            ctxs = [shared] * n_streams
        elif mode == "par_comm+orig":
            world = CommWorld(num_vcis=1)
            rt = CommRuntime(world, progress="global", token_impl="data")
            ctxs = [world.create(f"c{s}", kind=kind) for s in range(n_streams)]
        elif mode == "par_comm+vcis":
            world = CommWorld(num_vcis=n_streams + 1)
            rt = CommRuntime(world, progress="hybrid",
                             join_every=4 * n_streams, token_impl="data")
            ctxs = [world.create(f"c{s}", kind=kind) for s in range(n_streams)]
        elif mode == "endpoints":
            world = CommWorld(num_vcis=n_streams + 1)
            rt = CommRuntime(world, progress="per_vci", token_impl="data")
            ctxs = [world.create(f"c{s}", kind=kind, vci=(s % world.pool.num_vcis))
                    for s in range(n_streams)]
        else:
            raise ValueError(mode)

        outs = []
        for s in range(n_streams):
            v = x[s]
            for _ in range(OPS_PER_STREAM):
                if rma:
                    v = rt.put(v, ctxs[s], axis="data", perm=perm)
                else:
                    v = rt.sendrecv(v, ctxs[s], axis="data", perm=perm)
            outs.append(v)
        return rt.barrier(jnp.stack(outs))

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=P(None, None),
                              out_specs=P(None, None), check_vma=False))
    x = jnp.ones((n_streams, msg_elems), jnp.float32)
    hlo = f.lower(x).compile().as_text()
    f(x)  # warm
    return f, x, hlo


MODES = ["everywhere", "ser_comm+orig", "ser_comm+vcis", "par_comm+orig",
         "par_comm+vcis", "endpoints"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rma", action="store_true", help="MPI_Put (Figs 13/14)")
    ap.add_argument("--no-token", action="store_true",
                    help="Fig 12: disable locking/atomics analogue")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--sizes", type=int, nargs="*",
                    default=[2, 512, 8192])   # 8B .. 32KB messages
    ap.add_argument("--streams", type=int, nargs="*",
                    default=[1, 2, 4, 8, 16])
    args = ap.parse_args()

    mesh = mesh_1d(args.devices)
    name = "message_rate" + ("_rma" if args.rma else "")
    csv = CSV(name)

    from repro.launch.roofline import collective_critical_depth

    for msg in args.sizes:
        for ns in args.streams:
            for mode in MODES:
                f, x, hlo = build_step(mode, ns, msg, rma=args.rma, mesh=mesh,
                                       no_token=args.no_token and
                                       mode == "par_comm+vcis")
                t = time_fn(lambda: block(f(x)))
                n_msgs = ns * OPS_PER_STREAM * mesh.size
                d = collective_critical_depth(hlo)
                # projected rate on a parallel network: depth is the serial
                # bottleneck, so rate scales with ops/depth (the structural
                # analogue of the paper's thread-scaling curves)
                csv.add(mode=mode, streams=ns, msg_bytes=msg * 4,
                        mmsgs_per_s=n_msgs / t["median_s"] / 1e6,
                        us_per_step=t["median_s"] * 1e6,
                        critical_depth=d["critical_depth"],
                        parallelism=round(d["parallelism"], 3))
    csv.dump()


if __name__ == "__main__":
    main()
