"""Bucket-ready overlap scheduling — step time and exposed-comm fraction
vs ``schedule`` x ``num_vcis`` x ``optimizer`` (the training-side Fig. 17:
same wire bytes per step, lower critical path).

Two complementary measurements per cell:

**Modeled exposed-comm timeline** (the headline; hardware-independent).
The backward is normalized to 1.0 time units, spread over a layer-major
gradient tree (a real arch's shapes with the layer stack unstacked, so
cotangents become ready in reverse layer order like a DDP backward). Each
bucket's reduce *arrives* at the wire either when the backward ENDS
(``schedule="post"``: one post-pass over the finished gradient tree) or
the moment the bucket's cotangents exist (``schedule="overlap"``:
the ``custom_vjp`` bucket boundaries issue reduces inside the backward).
The wire is a fluid simulation with the paper's two rate limits:

* one VCI sustains only ``--vci-rate`` of line rate (the message-rate /
  channel-occupancy limit the paper's Figs. 10-11 measure — the reason a
  single stream cannot saturate the NIC), and
* all active VCIs together are capped at line rate.

``exposed_comm`` is wire time remaining after the backward ends — the part
of communication the step actually waits for. Total comm bytes are
IDENTICAL between schedules (the wire_bytes column): overlap moves time,
not traffic. ZeRO-1 cells model the full cycle — per-bucket grad
reduce_scatter, the global-norm-clip psum barrier (every gather needs the
clip scale, so gathers start after the LAST scatter lands), then the
updated-param all_gathers.

**Measured step** (8-device CPU mesh; wall clock is a proxy). The REAL
``make_train_step(schedule=...)`` is compiled and timed, and the HLO's
collective structure recorded. Fidelity note: the emulation serializes
same-VCI buckets via trace-level ordering tokens, which cannot span the
per-bucket ``custom_vjp`` boundaries — overlap cells therefore lose the
cross-bucket same-VCI serialization that the model (and real NIC hardware)
still charges. Directionality, not microseconds, is the claim transferred
to the TPU target (see benchmarks/common.py).

Emits ``BENCH_overlap_schedule.json`` with a summary comparing modeled
exposed-comm time, overlap vs post, at 8 VCIs for both optimizers.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV, SMOKE, block, emit_json, mesh_1d, time_fn
from repro.compat import set_mesh
from repro.core import get_comm_plan
from repro.launch.roofline import collective_critical_depth


# ---------------------------------------------------------------------------
# the gradient tree the timeline is modeled on
# ---------------------------------------------------------------------------

def layered_grads_struct(arch: str, layers: int):
    """Leaf structs in FORWARD USE ORDER: embed, then layer 0..L-1 params
    (the stacked layer dim unstacked), then the tail (final norm / head).
    A list pytree flattens in exactly this order, which is what
    ``plan_buckets(partition="contig")`` and the readiness model consume."""
    from repro.configs import get_config
    from repro.models.transformer import init_params

    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    named = {}

    def add(path, leaf):
        named["/".join(str(getattr(k, "key", k)) for k in path)] = leaf

    jax.tree_util.tree_map_with_path(add, struct)
    head, stacked, tail = [], [], []
    for name, leaf in named.items():
        if name.startswith("layers"):
            stacked.append((name, leaf))
        elif name.startswith("embed"):
            head.append((name, leaf))
        else:
            tail.append((name, leaf))
    ordered, names = [], []
    for name, leaf in head:
        ordered.append(jax.ShapeDtypeStruct(leaf.shape, jnp.float32))
        names.append(name)
    for i in range(layers):
        for name, leaf in stacked:
            ordered.append(jax.ShapeDtypeStruct(leaf.shape[1:], jnp.float32))
            names.append(f"{name}/{i}")
    for name, leaf in tail:
        ordered.append(jax.ShapeDtypeStruct(leaf.shape, jnp.float32))
        names.append(name)
    return ordered, names


# ---------------------------------------------------------------------------
# the wire model
# ---------------------------------------------------------------------------

def simulate_wire(arrivals, costs, vci_of, *, vci_rate: float):
    """Fluid sim of per-VCI FIFO channels over a shared line.

    ``costs`` are in line-rate seconds. Each VCI transfers its queue head
    at ``vci_rate`` of line rate; all active heads together are capped at
    line rate (fair-shared when oversubscribed). Returns per-item finish
    times."""
    m = len(costs)
    remaining = [float(c) for c in costs]
    finish = [None] * m
    queues: dict = {}
    for i in sorted(range(m), key=lambda i: (arrivals[i], i)):
        queues.setdefault(vci_of[i], []).append(i)
    t = 0.0
    while any(f is None for f in finish):
        heads = []
        for q in queues.values():
            for i in q:
                if finish[i] is None:
                    if arrivals[i] <= t + 1e-12:
                        heads.append(i)
                    break
        if not heads:
            t = min(arrivals[i] for i in range(m)
                    if finish[i] is None and arrivals[i] > t)
            continue
        per = min(vci_rate, 1.0 / len(heads))
        dt = min(remaining[i] / per for i in heads)
        future = [arrivals[i] - t for i in range(m)
                  if finish[i] is None and arrivals[i] > t + 1e-12]
        if future:
            dt = min(dt, min(future))
        for i in heads:
            remaining[i] -= per * dt
        t += dt
        for i in heads:
            if remaining[i] <= 1e-9:
                finish[i] = t
    return finish


def model_cell(structs, *, schedule: str, optimizer: str, num_vcis: int,
               streams: int, n: int, comm_ratio: float, vci_rate: float,
               wire_bytes: int):
    """Modeled (exposed_comm, step_time, wire_bytes) for one cell."""
    cp = get_comm_plan(structs, num_streams=streams, num_vcis=num_vcis,
                       schedule=schedule, persistent=False)
    plan = cp.plan
    vci_of = [ctx.vci.index for ctx in cp.contexts]

    sizes = [0] * plan.num_leaves
    for b in plan.buckets:
        for s in b.slots:
            sizes[s.index] = s.size
    total = float(sum(sizes))
    # cotangent of leaf i lands when the backward has walked back through
    # every leaf used after it (compute time ~ leaf sizes)
    prefix = np.cumsum([0.0] + sizes) / total
    ready = [1.0 - prefix[min(s.index for s in b.slots)]
             for b in plan.buckets]

    ring = (n - 1) / n
    # payload bytes (slot sizes, no alignment padding) are IDENTICAL across
    # partitions by construction — the "same traffic" claim is stated on
    # these; the timeline costs below use padded buffer sizes, which is
    # what each bucket actually puts on the wire.
    payload_elems = sum(s.size for b in plan.buckets for s in b.slots)
    phases = 2  # zero1: scatter + gather; replicated: all_reduce's 2x ring
    per_elem = wire_bytes if optimizer == "zero1" else 4
    payload_bytes = phases * ring * payload_elems * per_elem
    if optimizer == "zero1":
        scatter_bytes = [ring * b.padded_size * wire_bytes
                         for b in plan.buckets]
        gather_bytes = list(scatter_bytes)
        total_bytes = sum(scatter_bytes) + sum(gather_bytes)
    else:
        reduce_bytes = [2 * ring * b.padded_size * 4 for b in plan.buckets]
        total_bytes = sum(reduce_bytes)
    # comm_ratio = (total comm at LINE rate) / backward time
    beta = comm_ratio / total_bytes

    issue = ready if schedule == "overlap" else [1.0] * plan.num_buckets
    if optimizer == "zero1":
        costs = [beta * x for x in scatter_bytes]
        sc_fin = simulate_wire(issue, costs, vci_of, vci_rate=vci_rate)
        t_clip = max(sc_fin)  # global-norm clip psum: needs every shard
        order = cp.ready_order if schedule == "overlap" \
            else range(plan.num_buckets)
        g_arr = [0.0] * plan.num_buckets
        for pos, bid in enumerate(order):
            g_arr[bid] = t_clip + pos * 1e-9  # issue order ~ FIFO tie-break
        g_costs = [beta * x for x in gather_bytes]
        g_fin = simulate_wire(g_arr, g_costs, vci_of, vci_rate=vci_rate)
        t_end = max(max(sc_fin), max(g_fin))
    else:
        costs = [beta * x for x in reduce_bytes]
        fin = simulate_wire(issue, costs, vci_of, vci_rate=vci_rate)
        t_end = max(fin)
    exposed = max(0.0, t_end - 1.0)
    step_time = 0.5 + 1.0 + exposed  # forward ~ backward/2
    return dict(exposed_comm=exposed, model_step=step_time,
                exposed_frac=exposed / step_time, wire_bytes=total_bytes,
                payload_bytes=payload_bytes, buckets=plan.num_buckets,
                vcis_used=len(set(vci_of)))


# ---------------------------------------------------------------------------
# the measured (real train step) cells
# ---------------------------------------------------------------------------

def measure_cell(mesh, cfg, batch, *, schedule: str, optimizer: str,
                 num_vcis: int, streams: int):
    from repro.train.trainer import make_train_step, train_state_init

    state = train_state_init(cfg, jax.random.PRNGKey(0), optimizer=optimizer,
                             mesh=mesh, num_streams=streams,
                             schedule=schedule)
    step = make_train_step(cfg, mesh=mesh, comm="vci", num_streams=streams,
                           num_vcis=num_vcis, token_impl="data",
                           optimizer=optimizer, schedule=schedule)
    with set_mesh(mesh):
        jitted = jax.jit(step)
        hlo = jitted.lower(state, batch).compile().as_text()
        jitted(state, batch)
        t = time_fn(lambda: block(jitted(state, batch)), reps=5)
    d = collective_critical_depth(hlo)
    return dict(ms_per_step=t["median_s"] * 1e3,
                collectives=d["collective_count"],
                critical_depth=d["critical_depth"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--streams", type=int, default=8,
                    help="bucket count (one CommContext per bucket)")
    ap.add_argument("--arch", default="olmo-1b-smoke")
    ap.add_argument("--layers", type=int, default=8,
                    help="unstacked layer count for the timeline model")
    ap.add_argument("--comm-ratio", type=float, default=0.5,
                    help="total comm time at line rate / backward time")
    ap.add_argument("--vci-rate", type=float, default=0.25,
                    help="fraction of line rate ONE VCI can sustain (the "
                         "paper's single-channel message-rate limit)")
    ap.add_argument("--zero1-wire-bytes", type=int, default=2,
                    help="zero1 wire dtype size (2 = bf16)")
    args = ap.parse_args()

    mesh = mesh_1d(args.devices)
    n = mesh.size
    structs, _ = layered_grads_struct(args.arch, args.layers)
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    cfg = get_config(args.arch)
    batch = synthetic_batch(cfg, 2 * n, 32, seed=0)

    vci_counts = (1, 8) if SMOKE else (1, 2, 4, 8)
    measured_counts = (8,) if SMOKE else (1, 8)

    csv = CSV("overlap_schedule")
    rows = []
    for optimizer in ("replicated", "zero1"):
        for num_vcis in vci_counts:
            for schedule in ("post", "overlap"):
                row = dict(schedule=schedule, num_vcis=num_vcis,
                           optimizer=optimizer)
                row.update(model_cell(
                    structs, schedule=schedule, optimizer=optimizer,
                    num_vcis=num_vcis, streams=args.streams, n=n,
                    comm_ratio=args.comm_ratio, vci_rate=args.vci_rate,
                    wire_bytes=args.zero1_wire_bytes))
                if num_vcis in measured_counts:
                    row.update(measure_cell(
                        mesh, cfg, batch, schedule=schedule,
                        optimizer=optimizer, num_vcis=num_vcis,
                        streams=args.streams))
                else:
                    row.update(ms_per_step=None, collectives=None,
                               critical_depth=None)
                csv.add(**row)
                rows.append(row)
    csv.dump()

    def cell(schedule, optimizer, num_vcis):
        return next(r for r in rows if r["schedule"] == schedule
                    and r["optimizer"] == optimizer
                    and r["num_vcis"] == num_vcis)

    summary = {"comm_ratio": args.comm_ratio, "vci_rate": args.vci_rate,
               "devices": n, "streams": args.streams}
    for optimizer in ("replicated", "zero1"):
        post8 = cell("post", optimizer, 8)
        ovl8 = cell("overlap", optimizer, 8)
        summary[optimizer] = {
            "exposed_post_8vcis": post8["exposed_comm"],
            "exposed_overlap_8vcis": ovl8["exposed_comm"],
            # the acceptance claim: overlap reduces modeled exposed-comm
            # time vs the post schedule at 8 VCIs
            "exposed_ratio_8vcis": (ovl8["exposed_comm"]
                                    / max(post8["exposed_comm"], 1e-12)),
            "model_step_speedup_8vcis": (post8["model_step"]
                                         / ovl8["model_step"]),
            # same traffic, different timing: overlap moves bytes earlier,
            # it does not add or remove any. Stated on PAYLOAD bytes (slot
            # sizes), which are partition-invariant by construction; padded
            # buffer totals (wire_bytes) can differ slightly because the
            # two schedules use different partitions of the same leaves.
            "wire_bytes_equal": (post8["payload_bytes"]
                                 == ovl8["payload_bytes"]),
            "wire_bytes_per_step": post8["wire_bytes"],
            "payload_bytes_per_step": post8["payload_bytes"],
        }
        print(f"# {optimizer}: modeled exposed comm at 8 VCIs "
              f"{post8['exposed_comm']:.3f} (post) -> "
              f"{ovl8['exposed_comm']:.3f} (overlap), "
              f"{summary[optimizer]['exposed_ratio_8vcis']:.2f}x, "
              f"wire bytes equal: "
              f"{summary[optimizer]['wire_bytes_equal']}")
    emit_json("overlap_schedule", {"rows": rows, "summary": summary})


if __name__ == "__main__":
    main()
