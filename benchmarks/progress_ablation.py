"""Multi-VCI optimization ablations — paper Figs. 5, 6, 7, 8 and 19.

Starting from all optimizations ON (par_comm + VCIs + hybrid progress +
per-VCI staging + tile alignment), disable one at a time:

  all                  everything on (the paper's optimized library)
  no_per_vci_progress  progress=global: every op joins every stream
                       (6.97x in the paper)
  no_per_vci_req       staging="shared": all buckets through ONE staging
                       buffer (the request-pool lock; 39.98x in the paper)
  no_cache_align       align=1: streams share tiles (false sharing; 1.49x)
  single_vci           pool of 1: Fig 5's "multiple VCIs but no benefit"

Fig 19 (--receiver): N dominant senders, ONE polling receiver that must
iterate over all the senders' contexts (MPI-3.1 semantics) vs endpoints
(receiver addresses one pinned stream directly).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.core.bucketing import TILE, plan_buckets, reduce_gradients
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.launch.roofline import collective_critical_depth
from repro.compat import shard_map

N_STREAMS = 8


def grad_tree(key, n_devices, n_leaves=24, base=256):
    # leading dim sharded over devices => per-shard values DIFFER, so the
    # psum is a real all-reduce (replicated inputs let XLA elide it).
    ks = jax.random.split(key, n_leaves)
    return {f"w{i}": jax.random.normal(ks[i], (n_devices, base + 32 * i))
            for i in range(n_leaves)}


def build(variant: str, mesh):
    tree = grad_tree(jax.random.PRNGKey(0), mesh.size)

    progress = "global" if variant == "no_per_vci_progress" else "hybrid"
    staging = "shared" if variant == "no_per_vci_req" else "per_vci"
    align = 1 if variant == "no_cache_align" else TILE
    num_vcis = 1 if variant == "single_vci" else N_STREAMS + 1

    def step(tr):
        world = CommWorld(num_vcis=num_vcis)
        rt = CommRuntime(world, progress=progress, join_every=2 * N_STREAMS,
                         token_impl="data")
        plan = plan_buckets(tr, N_STREAMS, align=align)
        out = reduce_gradients(rt, tr, plan, axis="data", staging=staging)
        return rt.barrier(out)

    in_specs = jax.tree_util.tree_map(lambda _: P("data"), tree)
    out_specs = jax.tree_util.tree_map(lambda _: P(), tree)
    f = jax.jit(shard_map(step, mesh=mesh, in_specs=(in_specs,),
                          out_specs=out_specs, check_vma=False))
    return f, tree


VARIANTS = ["all", "no_per_vci_progress", "no_per_vci_req", "no_cache_align",
            "single_vci"]


def bench_ablation(mesh):
    csv = CSV("progress_ablation")
    base = None
    for variant in VARIANTS:
        f, tree = build(variant, mesh)
        hlo = f.lower(tree).compile().as_text()
        f(tree)
        t = time_fn(lambda: block(f(tree)))
        d = collective_critical_depth(hlo)
        us = t["median_s"] * 1e6
        if variant == "all":
            base = us
        # `collective_count`: independent streams let XLA's combiner batch
        # the buckets into ONE fused all-reduce (count 1, depth 1) — message
        # aggregation only legal because the streams are unchained. The
        # serialized variants keep 8 chained ops (count 8, depth 8).
        csv.add(variant=variant, us_per_step=us,
                slowdown_vs_all=us / base,
                collective_count=d["collective_count"],
                critical_depth=d["critical_depth"])
    csv.dump()


def bench_receiver(mesh):
    """Fig 19: dedicated receiver iterating over sender communicators."""
    csv = CSV("dedicated_receiver")
    n = mesh.size
    perm = [(i, (i + 1) % n) for i in range(n)]
    OPS = 8

    for n_senders in (1, 2, 4, 8):
        for mode in ("communicators", "endpoints"):
            def step(x):
                world = CommWorld(num_vcis=n_senders + 1)
                if mode == "endpoints":
                    rt = CommRuntime(world, progress="per_vci",
                                     token_impl="data")
                    ctxs = [world.create(f"c{s}", vci=s % world.pool.num_vcis)
                            for s in range(n_senders)]
                else:
                    rt = CommRuntime(world, progress="hybrid",
                                     join_every=4 * n_senders,
                                     token_impl="data")
                    ctxs = [world.create(f"c{s}") for s in range(n_senders)]
                sent = []
                for s in range(n_senders):
                    v = x[s]
                    for _ in range(OPS):
                        v = rt.sendrecv(v, ctxs[s], axis="data", perm=perm)
                    sent.append(v)
                # the RECEIVER side: with communicators it must poll every
                # context in turn (chained waits); with endpoints each pair
                # is independent and the receive is the stream tail itself.
                if mode == "communicators":
                    acc = jnp.zeros_like(x[0])
                    for s in range(n_senders):
                        acc = acc + rt.wait(
                            type("R", (), {"value": sent[s],
                                           "ctx": ctxs[s]})())
                    out = acc
                else:
                    out = sum(sent)
                return rt.barrier(out)

            f = jax.jit(shard_map(step, mesh=mesh, in_specs=P(None, None),
                                  out_specs=P(None), check_vma=False))
            x = jnp.ones((n_senders, 256), jnp.float32)
            hlo = f.lower(x).compile().as_text()
            f(x)
            t = time_fn(lambda: block(f(x)))
            d = collective_critical_depth(hlo)
            csv.add(mode=mode, senders=n_senders,
                    us_per_step=t["median_s"] * 1e6,
                    msgs_per_s=n_senders * OPS * n / t["median_s"],
                    critical_depth=d["critical_depth"])
    csv.dump()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--receiver", action="store_true")
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)
    if args.receiver:
        bench_receiver(mesh)
    else:
        bench_ablation(mesh)
        bench_receiver(mesh)


if __name__ == "__main__":
    main()
