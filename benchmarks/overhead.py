"""Thread-safety overhead + multi-VCI setup cost — paper Figs. 2, 3, 4.

Fig 2/3: fine-grained (per-VCI tokens) vs Global (one token) in the
UNCONTENDED case (1 stream) and the crossover as streams grow. On CPU the
lock cost appears as (a) extra token ops on the critical path (measured:
us/step) and (b) the structural depth.

Fig 4: MPI_Init/Finalize time vs #VCIs — here: trace+lower+compile time of
a step using K streams (each VCI = an independent collective chain => more
HLO to build and schedule).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.compat import shard_map

OPS = 32


def build(mode: str, n_streams: int, mesh, msg=128):
    def step(x):
        if mode == "global":
            world = CommWorld(num_vcis=1)
            rt = CommRuntime(world, progress="global", token_impl="data")
            ctxs = [world.world] * n_streams
        else:  # fg
            world = CommWorld(num_vcis=n_streams + 1)
            rt = CommRuntime(world, progress="hybrid",
                             join_every=4 * n_streams, token_impl="data")
            ctxs = [world.create(f"c{s}") for s in range(n_streams)]
        outs = []
        for s in range(n_streams):
            v = x[s]
            for _ in range(OPS):
                v = rt.all_reduce(v, ctxs[s], axis="data")
            outs.append(v)
        return rt.barrier(jnp.stack(outs))

    return jax.jit(shard_map(step, mesh=mesh, in_specs=P(None, None),
                             out_specs=P(None, None), check_vma=False))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)

    csv = CSV("overhead_fg_vs_global")
    for ns in (1, 2, 4, 8, 16):
        x = jnp.ones((ns, 128), jnp.float32)
        for mode in ("global", "fg"):
            f = build(mode, ns, mesh)
            f(x)
            t = time_fn(lambda: block(f(x)))
            csv.add(mode=mode, streams=ns, us_per_step=t["median_s"] * 1e6,
                    us_per_op=t["median_s"] * 1e6 / (ns * OPS))
    csv.dump()

    # Fig 4: setup (compile) cost vs pool size
    csv2 = CSV("overhead_setup_vs_vcis")
    for nv in (1, 2, 4, 8, 16, 32):
        x = jnp.ones((nv, 128), jnp.float32)
        f = build("fg", nv, mesh)
        t0 = time.perf_counter()
        f.lower(x).compile()
        csv2.add(num_vcis=nv, compile_s=time.perf_counter() - t0)
    csv2.dump()


if __name__ == "__main__":
    main()
