"""BSPMM get-compute-update — paper §6.3, Fig. 27 (category 3: MPI
semantics limit exposable parallelism).

NWChem's tensor-contraction pattern: each worker Gets A/B tiles (its own
window — fine), multiplies, then ACCUMULATES into the shared C window.
MPI-3.1 forces every thread onto ONE window for MPI_Accumulate (atomicity
across windows is undefined) and orders same-location accumulates, so the
accumulate stream serializes. The three ways out, all measured:

  mpi31_ordered     one C window, ordered accumulates (the constraint)
  mpi31_relaxed     accumulate_ordering="none" (the paper's §6.3 hint)
  endpoints         per-thread endpoints INSIDE one window (the proposal)
  everywhere        MPI-everywhere baseline (no tokens)

Paper's finding: ordered accumulates serialize; the hint restores endpoint
parity — extensions to the standard are not required.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.launch.roofline import collective_critical_depth
from repro.compat import shard_map

N_WORKERS = 8


def build(mode: str, tile: int, mesh):
    n = mesh.size
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(a_tiles, b_tiles):
        if mode == "everywhere":
            outs = []
            for w in range(N_WORKERS):
                a = jax.lax.ppermute(a_tiles[w], "data", perm)
                b = jax.lax.ppermute(b_tiles[w], "data", perm)
                c = a @ b
                outs.append(jax.lax.psum(c, "data"))
            return jnp.stack(outs)

        world = CommWorld(num_vcis=N_WORKERS + 1)
        if mode == "endpoints":
            rt = CommRuntime(world, progress="per_vci", token_impl="data")
            getw = [world.create(f"g{w}", kind="rma", vci=w + 1)
                    for w in range(N_WORKERS)]
            # endpoints: each thread its own stream INSIDE the C window
            accw = [world.create(f"acc{w}", kind="rma", vci=w + 1,
                                 accumulate_ordering="none")
                    for w in range(N_WORKERS)]
        else:
            rt = CommRuntime(world, progress="hybrid",
                             join_every=4 * N_WORKERS, token_impl="data")
            getw = [world.create(f"g{w}", kind="rma")
                    for w in range(N_WORKERS)]
            ordering = "none" if mode == "mpi31_relaxed" else "rar"
            cwin = world.create("C", kind="rma",
                                accumulate_ordering=ordering)
            accw = [cwin] * N_WORKERS
        outs = []
        for w in range(N_WORKERS):
            a = rt.get(a_tiles[w], getw[w], axis="data", perm=perm)
            b = rt.get(b_tiles[w], getw[w], axis="data", perm=perm)
            c = a @ b
            outs.append(rt.accumulate(c, accw[w], axis="data"))
        return rt.barrier(jnp.stack(outs))

    f = jax.jit(shard_map(step, mesh=mesh,
                          in_specs=(P(None, None, None),) * 2,
                          out_specs=P(None, None, None),
                          check_vma=False))
    a = jnp.ones((N_WORKERS, tile, tile), jnp.float32)
    return f, a


MODES = ["everywhere", "mpi31_ordered", "mpi31_relaxed", "endpoints"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)
    csv = CSV("bspmm")
    for tile in (32, 128, 256):
        for mode in MODES:
            f, a = build(mode, tile, mesh)
            hlo = f.lower(a, a).compile().as_text()
            f(a, a)
            t = time_fn(lambda: block(f(a, a)))
            d = collective_critical_depth(hlo)
            csv.add(mode=mode, tile=tile,
                    us_per_workunit=t["median_s"] * 1e6 / N_WORKERS,
                    critical_depth=d["critical_depth"],
                    parallelism=round(d["parallelism"], 3))
    csv.dump()


if __name__ == "__main__":
    main()
