"""Serve-path VCI streams — decode throughput vs. pool size.

G concurrently-decoding batches ("lanes") are traced into ONE program; each
lane's TP all-reduces, MoE combines and sampling gathers ride its own
per-purpose CommContexts, all drawn from one ``ServeCommPlan`` sharing one
``CommRuntime`` (so contexts that collide in the VCI pool chain on the same
ordering token and serialize — the serve-side Fig. 17). Sweeping
``num_vcis`` from 1 (everything on the fallback stream: the paper's "one
global stream" anti-pattern, Fig. 4) up past the live context count shows
where the decode-throughput headroom lives.

Reported per cell: decode tok/s, ms/step, HLO collective count + critical
depth (the structural metric that transfers to the TPU target), and the
realized pool statistics.

The ENGINE cells (``engine_rows``) run the full continuous-batching
``ServeEngine`` under mixed-length traffic — paged KV cache vs contiguous,
at VCI pool sizes 1/4/8 — and report end-to-end tok/s plus
``cache_bytes_resident``: the paged pool is sized to the live-token budget
(finished slots' pages reclaim immediately; admission allocates on entry),
so it holds the SAME tokens in fewer resident bytes than the
``batch x max_len`` contiguous cache.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from benchmarks.common import CSV, SMOKE, block, emit_json, time_fn
from repro.compat import set_mesh, shard_map
from repro.configs import get_config
from repro.launch.roofline import collective_critical_depth
from repro.models.transformer import Model, init_cache, init_params
from repro.serve.comm import ServeCommPlan, serve_cache_specs, \
    serve_param_specs, serve_tp_validate
from repro.serve.engine import Request, ServeEngine, greedy_sample, \
    make_prefill

MAX_LEN = 64
PROMPT = 16

# engine (continuous-batching) cells: mixed-length traffic. max_len stays
# at/below mixtral's sliding window so the MoE arch keeps a non-ring cache
# (ring caches have no paged layout).
ENGINE_MAX_LEN = 64
ENGINE_BATCH = 4
ENGINE_PAGE = 8
ENGINE_PAGES = 17           # 16 allocatable pages = 128 live-token slots


def serve_mesh(devices: int, tp: int = 2) -> Mesh:
    devs = jax.devices()
    if len(devs) < devices:
        raise RuntimeError(f"need {devices} devices, have {len(devs)} — run "
                           f"via benchmarks.run or set XLA_FLAGS")
    return Mesh(np.array(devs[:devices]).reshape(devices // tp, tp),
                ("data", "model"))


def make_multilane_step(cfg, mesh, plan: ServeCommPlan, lanes: int):
    """One traced decode step advancing ``lanes`` independent batches; lane
    g's collectives are issued on lane g's contexts, one shared runtime."""
    tp = dict(mesh.shape)["model"]
    serve_tp_validate(cfg, tp)
    nb = dict(mesh.shape)["data"]

    def step(params, toks, caches):
        bd = "data" if toks[0].shape[0] % nb == 0 else None
        nshard = nb if bd is not None else 1

        def inner(params, toks, caches):
            rt = plan.runtime()
            out_t, out_c = [], []
            for g in range(lanes):
                comm = plan.comm(g, rt=rt)
                model = Model(cfg, None, comm=comm)
                logits, nc = model.decode_step(params, toks[g], caches[g])
                out_t.append(greedy_sample(logits))
                out_c.append(nc)
            out_t[0] = rt.barrier(out_t[0])  # drain every stream
            return tuple(out_t), tuple(out_c)

        cspecs = tuple(serve_cache_specs(c, tp, nshard) for c in caches)
        f = shard_map(
            inner, mesh=mesh,
            in_specs=(serve_param_specs(cfg, params, tp),
                      tuple(P(bd, None) for _ in toks), cspecs),
            out_specs=(tuple(P(bd, None) for _ in toks), cspecs),
            check_vma=False, axis_names=set(mesh.axis_names))
        return f(params, toks, caches)

    return step


def run_cell(cfg, params, mesh, *, batch: int, lanes: int, num_vcis: int,
             policy: str, steps: int):
    plan = ServeCommPlan(num_vcis=num_vcis, vci_policy=policy, lanes=lanes,
                         token_impl="data")
    rng = np.random.default_rng(0)
    prefill = jax.jit(make_prefill(cfg, mesh, plan))
    toks, caches = [], []
    with set_mesh(mesh):
        for g in range(lanes):
            prompts = rng.integers(0, cfg.vocab_size, (batch, PROMPT),
                                   dtype=np.int32)
            cache = init_cache(cfg, batch, MAX_LEN, dtype=jnp.float32)
            nxt, cache = prefill(params, {"tokens": jnp.asarray(prompts)},
                                 cache, jnp.zeros((batch,), jnp.int32),
                                 jnp.zeros((batch,), jnp.float32),
                                 jax.random.PRNGKey(g))
            toks.append(nxt)
            caches.append(cache)
        toks, caches = tuple(toks), tuple(caches)
        jitted = jax.jit(make_multilane_step(cfg, mesh, plan, lanes))
        hlo = jitted.lower(params, toks, caches).compile().as_text()

        def run():
            t, c = toks, caches
            for _ in range(steps):
                t, c = jitted(params, t, c)
            block((t, c))

        t = time_fn(run, reps=3 if SMOKE else 7)
    d = collective_critical_depth(hlo)
    ms_per_step = t["median_s"] * 1e3 / steps
    return {
        "ms_per_step": ms_per_step,
        "tok_s": lanes * batch / (ms_per_step / 1e3),
        "collectives": d["collective_count"],
        "critical_depth": d["critical_depth"],
        "parallelism": round(d["parallelism"], 3),
        "fallback_hits": plan.stats.fallback_hits,
        "max_ctx_per_vci": plan.stats.max_contexts_per_vci,
    }


def engine_requests(cfg, n: int, max_new: int):
    """Mixed-length traffic: prompt lengths in [8, 16] — the --vary-prompts
    shape the left-padded/paged paths exist for."""
    rng = np.random.default_rng(1)
    return [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(8, 17)),),
                                        dtype=np.int32),
                    max_new_tokens=max_new) for _ in range(n)]


def run_engine_cell(cfg, params, mesh, *, paged: bool, num_vcis: int,
                    requests: int, max_new: int):
    """End-to-end continuous batching: #requests > batch_size so slots
    recycle mid-stream (paged admission runs under the mesh)."""
    plan = ServeCommPlan(num_vcis=num_vcis, token_impl="data")
    eng = ServeEngine(cfg, params, batch_size=ENGINE_BATCH,
                      max_len=ENGINE_MAX_LEN, mesh=mesh, comm_plan=plan,
                      paged=paged, page_size=ENGINE_PAGE,
                      num_pages=ENGINE_PAGES if paged else None)
    assert eng._paged == paged, "paged engine silently fell back"
    eng.generate(engine_requests(cfg, requests, max_new))  # compile warmup
    t = time_fn(lambda: eng.generate(engine_requests(cfg, requests, max_new)),
                warmup=0, reps=2 if SMOKE else 3, min_time_s=0.0)
    n_tok = requests * max_new
    return {
        "cache": "paged" if paged else "contiguous",
        "tok_s": n_tok / t["median_s"],
        "cache_bytes_resident": eng.cache_bytes_resident,
        "admit_under_mesh": eng._can_admit,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--policy", default="fcfs")
    ap.add_argument("--steps", type=int, default=None,
                    help="decode steps per timed call")
    args = ap.parse_args()
    mesh = serve_mesh(args.devices, args.tp)
    steps = args.steps or (2 if SMOKE else 8)

    archs = ("olmo-1b-smoke", "mixtral-8x22b-smoke")
    batches = (4,) if SMOKE else (4, 8)
    vcis = (1, 8) if SMOKE else (1, 2, 4, 8)

    csv = CSV("serve_streams")
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        for batch in batches:
            for nv in vcis:
                r = run_cell(cfg, params, mesh, batch=batch,
                             lanes=args.lanes, num_vcis=nv,
                             policy=args.policy, steps=steps)
                row = dict(arch=arch, batch=batch, lanes=args.lanes,
                           num_vcis=nv, policy=args.policy, **r)
                rows.append(row)
                csv.add(**row)
    csv.dump()

    def cell(arch, batch, nv):
        return next(r for r in rows if r["arch"] == arch
                    and r["batch"] == batch and r["num_vcis"] == nv)

    # engine-level paged-vs-contiguous cells under mixed-length traffic
    eng_vcis = (1, 8) if SMOKE else (1, 4, 8)
    requests = 6 if SMOKE else 8
    max_new = 4 if SMOKE else 8
    eng_csv = CSV("serve_engine_paged")
    engine_rows = []
    for arch in archs:
        cfg = get_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        for paged in (False, True):
            for nv in eng_vcis:
                r = run_engine_cell(cfg, params, mesh, paged=paged,
                                    num_vcis=nv, requests=requests,
                                    max_new=max_new)
                row = dict(arch=arch, num_vcis=nv,
                           batch=ENGINE_BATCH, max_len=ENGINE_MAX_LEN,
                           requests=requests, max_new=max_new, **r)
                engine_rows.append(row)
                eng_csv.add(**row)
    eng_csv.dump()

    def eng_cell(arch, cache, nv):
        return next(r for r in engine_rows if r["arch"] == arch
                    and r["cache"] == cache and r["num_vcis"] == nv)

    # CPU-host wall clock is a PROXY (see benchmarks.common): tok/s cells
    # are reported per pool size, but the metric that transfers to the TPU
    # target is the collective critical depth — dedicated streams must
    # shorten it vs the single fallback stream.
    summary = {}
    for arch in archs:
        for batch in batches:
            lo = cell(arch, batch, vcis[0])
            hi = cell(arch, batch, max(vcis))
            summary[f"{arch}/b{batch}"] = {
                "tok_s_1vci": lo["tok_s"],
                "tok_s_maxvci": hi["tok_s"],
                "speedup": hi["tok_s"] / lo["tok_s"],
                "depth_1vci": lo["critical_depth"],
                "depth_maxvci": hi["critical_depth"],
            }
    # the paged acceptance cell: same tokens, fewer resident cache bytes
    engine_summary = {}
    for arch in archs:
        for nv in eng_vcis:
            c = eng_cell(arch, "contiguous", nv)
            p = eng_cell(arch, "paged", nv)
            engine_summary[f"{arch}/vcis{nv}"] = {
                "tok_s_contiguous": c["tok_s"],
                "tok_s_paged": p["tok_s"],
                "cache_bytes_contiguous": c["cache_bytes_resident"],
                "cache_bytes_paged": p["cache_bytes_resident"],
                "cache_bytes_ratio": (p["cache_bytes_resident"]
                                      / c["cache_bytes_resident"]),
            }
    emit_json("serve_streams", {"rows": rows, "engine_rows": engine_rows,
                                "summary": summary,
                                "engine_summary": engine_summary,
                                "mesh": {"devices": args.devices,
                                         "tp": args.tp,
                                         "lanes": args.lanes}})


if __name__ == "__main__":
    main()
