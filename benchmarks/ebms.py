"""EBMS energy-band remote fetch — paper §6.2, Figs. 24/25 (category 2:
shared progress).

Each worker (stream) fetches a band shard from a remote node: MPI_Get +
MPI_Win_flush. Modes: everywhere / par_win+vcis / endpoints, one window per
stream (the paper's Fig. 23 parallelism).

The paper's OPA cluster collapses here because software-emulated RMA needs
TARGET-side progress and independent VCIs oppose shared progress. TPU ICI
(like Mellanox IB in the paper) progresses RMA in hardware — collectives
complete without a target-side poll — so the interesting measurable is the
FLUSH dependency structure: per-VCI flush orders on ONE stream (cheap);
global-progress flush joins every stream (the paper's correctness fallback,
expensive). Both are reported.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import CSV, block, mesh_1d, time_fn
from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.launch.roofline import collective_critical_depth
from repro.compat import shard_map

N_WORKERS = 8


def build(mode: str, band_elems: int, mesh):
    n = mesh.size
    # each worker fetches from the next node (the band server)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(bands):
        if mode == "everywhere":
            outs = [jax.lax.ppermute(bands[w], "data", perm)
                    for w in range(N_WORKERS)]
            return jnp.stack(outs)
        world = CommWorld(num_vcis=N_WORKERS + 1)
        if mode == "endpoints":
            rt = CommRuntime(world, progress="per_vci", token_impl="data")
            wins = [world.create(f"w{w}", kind="rma", vci=w + 1)
                    for w in range(N_WORKERS)]
        elif mode == "par_win+vcis":
            rt = CommRuntime(world, progress="hybrid",
                             join_every=2 * N_WORKERS, token_impl="data")
            wins = [world.create(f"w{w}", kind="rma")
                    for w in range(N_WORKERS)]
        elif mode == "par_win+global_flush":
            # the correctness fallback: every flush does a global round
            rt = CommRuntime(world, progress="hybrid", join_every=1,
                             token_impl="data")
            wins = [world.create(f"w{w}", kind="rma")
                    for w in range(N_WORKERS)]
        else:
            raise ValueError(mode)
        fetched = [rt.get(bands[w], wins[w], axis="data", perm=perm)
                   for w in range(N_WORKERS)]
        flushed = [rt.flush(f_, wins[w]) for w, f_ in enumerate(fetched)]
        return rt.barrier(jnp.stack(flushed))

    f = jax.jit(shard_map(step, mesh=mesh, in_specs=P(None, None),
                          out_specs=P(None, None), check_vma=False))
    x = jnp.ones((N_WORKERS, band_elems), jnp.float32)
    return f, x


MODES = ["everywhere", "par_win+vcis", "par_win+global_flush", "endpoints"]


def build_busy_target(mode: str, burn_iters: int, mesh, band_elems=16384):
    """Figs. 15/16: the target is busy computing before its band is ready.

    The fetch's SOURCE value depends on a target-side compute chain of
    ``burn_iters`` matmuls — on OPA (software RMA) a busy target stalls
    completions; TPU ICI progresses RMA in hardware, so all modes degrade
    only by the unavoidable data dependency (the paper's UCX/IB curve).
    """
    n = mesh.size
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(bands, w):
        # target-side computation producing the band
        def burn(b):
            v = b[: 256].reshape(16, 16)
            for _ in range(burn_iters):
                v = jnp.tanh(v @ w)
            return b + jnp.sum(v) * 1e-9
        busy = [burn(bands[k]) for k in range(N_WORKERS)]
        if mode == "everywhere":
            fetched = [jax.lax.ppermute(b, "data", perm) for b in busy]
            return jnp.stack(fetched)
        world = CommWorld(num_vcis=N_WORKERS + 1)
        rt = CommRuntime(world, progress="hybrid", join_every=2 * N_WORKERS,
                         token_impl="data")
        wins = [world.create(f"w{k}", kind="rma") for k in range(N_WORKERS)]
        fetched = [rt.get(busy[k], wins[k], axis="data", perm=perm)
                   for k in range(N_WORKERS)]
        flushed = [rt.flush(f_, wins[k]) for k, f_ in enumerate(fetched)]
        return rt.barrier(jnp.stack(flushed))

    f = jax.jit(shard_map(step, mesh=mesh,
                          in_specs=(P(None, None), P()),
                          out_specs=P(None, None), check_vma=False))
    x = jnp.ones((N_WORKERS, band_elems), jnp.float32)
    w = jnp.eye(16, dtype=jnp.float32) * 0.5
    return f, x, w


def bench_busy_target(mesh):
    csv = CSV("ebms_busy_target")
    for burn in (0, 8, 64, 256):
        for mode in ("everywhere", "par_win+vcis"):
            f, x, w = build_busy_target(mode, burn, mesh)
            f(x, w)
            t = time_fn(lambda: block(f(x, w)))
            csv.add(mode=mode, burn_iters=burn,
                    us_per_fetch=t["median_s"] * 1e6 / N_WORKERS)
    csv.dump()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    mesh = mesh_1d(args.devices)
    csv = CSV("ebms_remote_fetch")
    for band in (1024, 65536, 1048576):  # 4KB .. 4MB bands
        for mode in MODES:
            f, x = build(mode, band, mesh)
            hlo = f.lower(x).compile().as_text()
            f(x)
            t = time_fn(lambda: block(f(x)))
            d = collective_critical_depth(hlo)
            csv.add(mode=mode, band_bytes=band * 4,
                    us_per_fetch=t["median_s"] * 1e6 / N_WORKERS,
                    critical_depth=d["critical_depth"],
                    parallelism=round(d["parallelism"], 3))
    csv.dump()
    bench_busy_target(mesh)


if __name__ == "__main__":
    main()
