"""Checkpointing: per-leaf .npy files + a JSON manifest.

Layout:
    <dir>/step_<N>/manifest.json       tree structure + dtypes + metadata
    <dir>/step_<N>/leaf_<i>.npy        one file per pytree leaf

Restore reshards: pass ``shardings`` (a matching pytree of NamedSharding)
and each leaf is device_put straight to its target layout. Loads are
host-local; multi-host restore maps each host's addressable shards (the
manifest stores the global shape).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for kp, _ in flat:
        paths.append(_SEP.join(_key_str(k) for k in kp))
    return paths, [v for _, v in flat], treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"[{k.idx}]"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    out = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(out, fname), arr)
        manifest["leaves"].append({
            "path": p, "file": fname, "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        })
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: Any,
                    shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (values ignored), optionally
    device_put onto ``shardings`` (same treedef)."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    paths, like_leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    if set(paths) != set(by_path):
        missing = set(paths) - set(by_path)
        extra = set(by_path) - set(paths)
        raise ValueError(f"checkpoint tree mismatch: missing={missing} extra={extra}")
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for p, lk, sh in zip(paths, like_leaves, shard_leaves):
        arr = np.load(os.path.join(src, by_path[p]["file"]))
        if tuple(arr.shape) != tuple(lk.shape):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs {lk.shape}")
        arr = arr.astype(lk.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
