"""Communication contexts — the MPI communicator / window analogue (§2).

A :class:`CommContext` is the *user-visible* handle through which an
application exposes logical communication parallelism, exactly as MPI-3.1
users do with communicators (point-to-point) and windows (RMA):

* two operations on **different** contexts are unordered — the library may
  map them to different VCIs and run them in parallel;
* two operations on the **same** context are FIFO-ordered (MPI's
  nonovertaking rule) — they share the context's VCI and are chained on its
  ordering token;
* a context created with ``vci=``-pinning is the **user-visible endpoint**
  mode: the user addresses the underlying interface directly, bypassing the
  library's mapping. This is the upper bound the paper compares against.

Matching semantics preserved from the standard (§2.1):

* ``kind="p2p"``: receive-side wildcards (``MPI_ANY_SOURCE``) force all
  receives of a communicator through one stream — contexts therefore default
  to ``ordered=True``; ``allow_wildcards=False`` is the MPI-4.0
  ``mpi_assert_no_any_source``-style hint that lets per-*rank* sub-streams
  exist (modelled here as permission to split one context into per-peer
  sub-contexts via :meth:`CommWorld.split`).
* ``kind="rma"``: Put/Get have no matching order; Accumulate is ordered by
  default with ``accumulate_ordering="none"`` available as a relaxation
  (§6.3) — see :meth:`repro.core.collectives.CommRuntime.accumulate`.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.vci import VCI, VCIPool


@dataclass(frozen=True)
class CommContext:
    name: str
    vci: VCI
    kind: str = "p2p"                 # "p2p" (communicator) | "rma" (window)
    ordered: bool = True              # FIFO stream (nonovertaking rule)
    accumulate_ordering: str = "rar"  # "rar" (default) | "none" (hint)
    pinned: bool = False              # user-visible-endpoint mode

    def __post_init__(self):
        assert self.kind in ("p2p", "rma")
        assert self.accumulate_ordering in ("rar", "none")


class CommWorld:
    """Host-side registry: context creation/freeing against the VCI pool.

    Mirrors MPI_Comm_create / MPI_Win_create mapping contexts to VCIs at
    creation time (paper §4.2). Built once; the traced step consumes the
    resulting contexts through a :class:`~repro.core.collectives.CommRuntime`.
    """

    def __init__(self, num_vcis: int = 8, policy: str = "fcfs"):
        self.pool = VCIPool(num_vcis=num_vcis, policy=policy)
        self._contexts: Dict[str, CommContext] = {}
        self._uid = itertools.count()
        # COMM_WORLD itself: the fallback VCI's resident context.
        self.world = self._register(
            CommContext("WORLD", VCI(VCIPool.FALLBACK), kind="p2p"))

    # ------------------------------------------------------------------
    def _register(self, ctx: CommContext) -> CommContext:
        self._contexts[ctx.name] = ctx
        return ctx

    def create(
        self,
        name: Optional[str] = None,
        *,
        kind: str = "p2p",
        hint: Optional[str] = None,
        accumulate_ordering: str = "rar",
        vci: Optional[int] = None,
    ) -> CommContext:
        """Create a communicator/window; the library maps it to a VCI.

        ``vci=`` pins the interface explicitly (user-visible endpoints).
        ``hint`` feeds the pool's ``hinted`` policy (§5.2 suggestion).
        """
        name = name or f"ctx{next(self._uid)}"
        if name in self._contexts:
            raise KeyError(f"context {name!r} exists")
        if vci is not None:
            if not (0 <= vci < self.pool.num_vcis):
                raise ValueError(f"vci {vci} outside pool of {self.pool.num_vcis}")
            ctx = CommContext(name, VCI(vci), kind=kind, pinned=True,
                              accumulate_ordering=accumulate_ordering)
            return self._register(ctx)
        v = self.pool.acquire(name, hint=hint)
        return self._register(CommContext(
            name, v, kind=kind, accumulate_ordering=accumulate_ordering))

    def free(self, ctx: CommContext) -> None:
        """MPI_Comm_free / MPI_Win_free: return the VCI to the pool."""
        del self._contexts[ctx.name]
        if not ctx.pinned and ctx.name != "WORLD":
            self.pool.release(ctx.name)

    def split(self, ctx: CommContext, n: int, *, hint: Optional[str] = None
              ) -> List[CommContext]:
        """Split a context into n independent sub-contexts (e.g. per peer,
        legal only under a no-wildcard assertion for p2p)."""
        return [self.create(f"{ctx.name}.{i}", kind=ctx.kind, hint=hint,
                            accumulate_ordering=ctx.accumulate_ordering)
                for i in range(n)]

    # ------------------------------------------------------------------
    def get(self, name: str) -> CommContext:
        return self._contexts[name]

    @property
    def stats(self):
        return self.pool.stats
