"""Gradient bucketing onto VCI streams — the training-loop integration.

The paper's headline microbenchmark is aggregate *message rate*: many small
messages injected in parallel over independent streams. The training-loop
equivalent is gradient reduction: a pytree of many small/medium tensors that
must be summed over the ``data`` axis every step. The serialized baseline
("global critical section") funnels everything through one stream as one
chain; the VCI design partitions the tree into B buckets, assigns each bucket
a CommContext (communicator analogue), and issues B independent
reduce-scatters/all-reduces that XLA may overlap.

Paper-optimization analogues carried over:

* per-VCI request cache (§4.3, 39.98x)  →  ``staging="per_vci"``: each bucket
  packs into its own freshly-allocated flat buffer. ``staging="shared"``
  reproduces the un-optimized path: every bucket is written into ONE shared
  staging array via dynamic_update_slice, which threads a value dependency
  through all buckets and serializes them (lock on the shared request pool).
* cache-line-aligned VCIs (§4.3, 1.49x) →  ``align``: bucket payloads are
  padded to tile-aligned sizes ((8,128) f32 tiles) so no two streams' bytes
  share a tile; ``align=1`` disables it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CommRuntime

TILE = 8 * 128  # one (8,128) f32 VREG/VMEM tile


@dataclass(frozen=True)
class LeafSlot:
    index: int            # position in the flattened tree
    shape: Tuple[int, ...]
    dtype: Any
    offset: int           # offset inside the bucket's flat buffer

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass(frozen=True)
class Bucket:
    bid: int
    slots: Tuple[LeafSlot, ...]
    padded_size: int


@dataclass(frozen=True)
class BucketPlan:
    treedef: Any
    buckets: Tuple[Bucket, ...]
    align: int

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_padded(self) -> int:
        return sum(b.padded_size for b in self.buckets)


def _round_up(n: int, align: int) -> int:
    return ((n + align - 1) // align) * align


def plan_buckets(tree, num_buckets: int, *, align: int = TILE) -> BucketPlan:
    """Greedy size-balanced partition of a pytree's leaves into buckets."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [int(np.prod(l.shape)) if l.shape else 1 for l in leaves]
    order = sorted(range(len(leaves)), key=lambda i: -sizes[i])
    num_buckets = max(1, min(num_buckets, len(leaves)))
    loads = [0] * num_buckets
    members: List[List[int]] = [[] for _ in range(num_buckets)]
    for i in order:
        b = loads.index(min(loads))
        members[b].append(i)
        loads[b] += sizes[i]
    buckets = []
    for bid, idxs in enumerate(members):
        idxs = sorted(idxs)
        slots, off = [], 0
        for i in idxs:
            slots.append(LeafSlot(i, tuple(leaves[i].shape), leaves[i].dtype, off))
            off += sizes[i]
        buckets.append(Bucket(bid, tuple(slots), _round_up(max(off, 1), align)))
    return BucketPlan(treedef, tuple(buckets), align)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack_bucket(leaves: Sequence[jax.Array], bucket: Bucket,
                dtype=jnp.float32) -> jax.Array:
    """Pack a bucket's leaves into one flat, tile-aligned buffer."""
    parts = []
    cursor = 0
    for s in bucket.slots:
        assert s.offset == cursor, "slots must be contiguous"
        parts.append(leaves[s.index].astype(dtype).reshape(-1))
        cursor += s.size
    pad = bucket.padded_size - cursor
    if pad:
        parts.append(jnp.zeros((pad,), dtype=dtype))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_bucket(flat: jax.Array, bucket: Bucket) -> List[Tuple[int, jax.Array]]:
    """Inverse of pack: returns (leaf_index, value) pairs."""
    out = []
    for s in bucket.slots:
        piece = lax_slice(flat, s.offset, s.offset + s.size)
        out.append((s.index, piece.reshape(s.shape).astype(s.dtype)))
    return out


def lax_slice(x, start, stop):
    return jax.lax.slice_in_dim(x, start, stop, axis=0)


# ---------------------------------------------------------------------------
# the bucketed reduction itself
# ---------------------------------------------------------------------------

def reduce_gradients(
    rt: CommRuntime,
    grads,
    plan: BucketPlan,
    *,
    axis="data",
    mean: bool = True,
    staging: str = "per_vci",
    reduce_dtype=jnp.float32,
    contexts=None,
):
    """All-reduce a gradient pytree over ``axis`` on VCI streams.

    One CommContext per bucket (created here unless supplied). With
    ``staging="shared"`` the packed buckets are first written into one shared
    flat buffer — the un-optimized request-pool path, kept for the ablation.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if contexts is None:
        contexts = [rt.world.create(kind="p2p") for _ in plan.buckets]

    packed = [pack_bucket(leaves, b, dtype=reduce_dtype) for b in plan.buckets]

    if staging == "shared":
        # One staging array; each bucket is inserted then re-extracted,
        # threading a value dependency through every stream (serialized).
        stage = jnp.zeros((plan.total_padded,), dtype=reduce_dtype)
        offs = np.cumsum([0] + [b.padded_size for b in plan.buckets])
        for i, p in enumerate(packed):
            stage = jax.lax.dynamic_update_slice(stage, p, (int(offs[i]),))
        packed = [jax.lax.dynamic_slice(stage, (int(offs[i]),),
                                        (plan.buckets[i].padded_size,))
                  for i in range(len(packed))]

    reduced = [rt.all_reduce(p, ctx, axis=axis)
               for p, ctx in zip(packed, contexts)]

    if mean:
        n = _axis_size(axis)
        reduced = [r / n for r in reduced]

    out_leaves: List[Optional[jax.Array]] = [None] * len(leaves)
    for flat, b in zip(reduced, plan.buckets):
        for idx, val in unpack_bucket(flat, b):
            out_leaves[idx] = val
    assert all(v is not None for v in out_leaves)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def _axis_size(axis) -> int:
    import jax.lax as lax
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= lax.axis_size(a)
        return n
    return lax.axis_size(axis)
