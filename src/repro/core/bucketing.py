"""Gradient bucketing onto VCI streams — the training-loop integration.

The paper's headline microbenchmark is aggregate *message rate*: many small
messages injected in parallel over independent streams. The training-loop
equivalent is gradient reduction: a pytree of many small/medium tensors that
must be summed over the ``data`` axis every step. The serialized baseline
("global critical section") funnels everything through one stream as one
chain; the VCI design partitions the tree into B buckets, assigns each bucket
a CommContext (communicator analogue), and issues B independent
reduce-scatters/all-reduces that XLA may overlap.

Paper-optimization analogues carried over:

* per-VCI request cache (§4.3, 39.98x)  →  ``staging="per_vci"``: each bucket
  packs into its own freshly-allocated flat buffer. ``staging="shared"``
  reproduces the un-optimized path: every bucket is written into ONE shared
  staging array via dynamic_update_slice, which threads a value dependency
  through all buckets and serializes them (lock on the shared request pool).
* cache-line-aligned VCIs (§4.3, 1.49x) →  ``align``: bucket payloads are
  padded to tile-aligned sizes ((8,128) f32 tiles) so no two streams' bytes
  share a tile; ``align=1`` disables it.

The FAST PATH (persistent comm plans + fused pack/unpack) adds three
orthogonal knobs, all reachable from :func:`reduce_gradients` and
``make_train_step``:

=============  =======================  =====================================
knob           values                   what changes
=============  =======================  =====================================
plan           per-step | persistent    :func:`get_comm_plan` caches the
                                        ``BucketPlan`` + ``CommWorld`` +
                                        contexts + pack index tables keyed on
                                        (treedef, shapes, knobs), so repeated
                                        ``train_step`` calls and jit retraces
                                        reuse ONE host-side plan (the §4.3
                                        per-VCI request-cache analogue).
pack           "xla" | "pallas"         "xla" packs each bucket with an
                                        O(leaves) concat chain; "pallas" lays
                                        grads into one tile-aligned arena and
                                        packs/unpacks per bucket with the
                                        ``bucket_pack_pallas`` /
                                        ``bucket_unpack_pallas`` tile-gather
                                        kernels on TPU. Off-TPU the same
                                        slot-aligned layout lowers to per-slot
                                        dynamic_update_slice DMA writes —
                                        ~2x the concat chain on the 8-device
                                        CPU mesh, where XLA:CPU materializes
                                        a copy per concat operand.
reduction      "all_reduce" |           "reduce_scatter" issues per-bucket
               "reduce_scatter"         psum_scatter + all_gather on the
                                        bucket's VCI stream — same result,
                                        half the bytes on the wire for DDP.
output         "tree" | "shards"        "tree" (default) returns the reduced
                                        pytree. "shards" (requires
                                        ``reduction="reduce_scatter"``) skips
                                        the re-gather and returns each rank's
                                        OWN slice of every reduced bucket plus
                                        the :class:`ShardLayout` describing
                                        ownership — the ZeRO-1 contract: a
                                        sharded optimizer consumes the shard
                                        directly and all-gathers the *updated
                                        params* instead (see
                                        ``repro.optim.adamw``), so gradient
                                        wire bytes are actually halved.
schedule       "post" | "overlap"       WHEN each bucket's reduce is issued.
                                        "post" (default) reduces after the
                                        full backward (one post-pass over the
                                        finished gradient tree). "overlap"
                                        wraps every bucket in a ``custom_vjp``
                                        boundary (:func:`overlap_boundaries`)
                                        so its reduce is issued on its VCI
                                        stream *inside the backward*, as soon
                                        as the bucket's cotangents exist —
                                        PyTorch-DDP bucket-ready hooks. Same
                                        wire bytes, shorter critical path:
                                        reduction becomes an event-driven
                                        consumer of the backward. Overlap
                                        plans partition leaves CONTIGUOUSLY
                                        in use order (``partition="contig"``)
                                        so buckets become ready progressively
                                        during the backward, and
                                        :func:`bucket_ready_order` gives the
                                        reverse-topological issue order.
=============  =======================  =====================================

``CommRuntime`` (and its ``ProgressEngine`` ordering tokens) is the ONLY
trace-dependent piece, so a persistent :class:`CommPlan` mints a fresh
runtime per trace via :meth:`CommPlan.runtime` while everything else is
built exactly once per (treedef, shapes, knobs).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CommRuntime
from repro.core.comm import CommContext, CommWorld

TILE = 8 * 128  # one (8,128) f32 VREG/VMEM tile


@dataclass(frozen=True)
class LeafSlot:
    index: int            # position in the flattened tree
    shape: Tuple[int, ...]
    dtype: Any
    offset: int           # offset inside the bucket's flat buffer

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass(frozen=True)
class Bucket:
    bid: int
    slots: Tuple[LeafSlot, ...]
    padded_size: int


@dataclass(frozen=True)
class BucketPlan:
    treedef: Any
    buckets: Tuple[Bucket, ...]
    align: int
    slot_align: Optional[int] = None  # per-slot alignment (pallas layout)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_padded(self) -> int:
        return sum(b.padded_size for b in self.buckets)

    @property
    def num_leaves(self) -> int:
        return sum(len(b.slots) for b in self.buckets)


def _round_up(n: int, align: int) -> int:
    return ((n + align - 1) // align) * align


@dataclass(frozen=True)
class ShardLayout:
    """Per-rank ownership of every bucket's flat buffer (the ZeRO-1 map).

    ``reduce_scatter`` over ``axis_size`` ranks splits bucket ``b``'s
    ``padded_size`` buffer into ``axis_size`` equal contiguous shards; rank
    ``r`` receives (and owns) elements ``[r*S_b, (r+1)*S_b)`` where
    ``S_b = padded_size / axis_size``. A sharded optimizer keeps moments and
    the fp32 master copy only for the owned range and all-gathers updated
    params back into the full buffer.

    Invariants (exercised by the property tests in ``tests/test_properties``):

    * every ``padded_size`` is divisible by ``axis_size`` (enforced at
      construction), so the ``axis_size`` shard ranges tile each bucket's
      ``[0, padded_size)`` exactly — no gap, no overlap;
    * every element of every :class:`LeafSlot` therefore has exactly ONE
      owning rank (:meth:`owner_of`); a slot that straddles a shard boundary
      is split between consecutive ranks (:meth:`slot_owners` returns the
      partition pieces);
    * pack → scatter → (zero update) → all_gather → unpack is the identity
      on the original leaves.
    """

    plan: BucketPlan
    axis_size: int

    def __post_init__(self):
        if self.axis_size < 1:
            raise ValueError(f"axis_size must be >= 1, got {self.axis_size}")
        for b in self.plan.buckets:
            if b.padded_size % self.axis_size:
                raise ValueError(
                    f"bucket {b.bid} padded_size {b.padded_size} not "
                    f"divisible by axis_size {self.axis_size}; plan with "
                    f"align a multiple of the axis size (TILE covers any "
                    f"2^k mesh up to 1024)")

    @property
    def num_buckets(self) -> int:
        return self.plan.num_buckets

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        """Per-bucket local shard length (``padded_size / axis_size``)."""
        return tuple(b.padded_size // self.axis_size
                     for b in self.plan.buckets)

    def shard_bounds(self, bid: int) -> Tuple[Tuple[int, int], ...]:
        """[start, stop) of every rank's shard of bucket ``bid``."""
        s = self.plan.buckets[bid].padded_size // self.axis_size
        return tuple((r * s, (r + 1) * s) for r in range(self.axis_size))

    def owner_of(self, bid: int, offset: int) -> int:
        """The unique rank owning flat ``offset`` of bucket ``bid``."""
        b = self.plan.buckets[bid]
        if not 0 <= offset < b.padded_size:
            raise IndexError(f"offset {offset} outside bucket {bid} "
                             f"[0, {b.padded_size})")
        return offset // (b.padded_size // self.axis_size)

    def slot_owners(self, bid: int, slot: LeafSlot
                    ) -> Tuple[Tuple[int, int, int], ...]:
        """Partition of a slot's range into (rank, start, stop) pieces.

        Pieces are contiguous, cover ``[slot.offset, slot.offset+size)``
        exactly, and carry strictly increasing ranks.
        """
        s = self.plan.buckets[bid].padded_size // self.axis_size
        out, cur = [], slot.offset
        end = slot.offset + slot.size
        while cur < end:
            r = cur // s
            stop = min(end, (r + 1) * s)
            out.append((r, cur, stop))
            cur = stop
        return tuple(out)

    @property
    def total_shard_elems(self) -> int:
        """Per-rank optimizer-state footprint in elements (the 1/N claim)."""
        return sum(self.shard_sizes)


def plan_buckets(tree, num_buckets: int, *, align: int = TILE,
                 slot_align: Optional[int] = None,
                 partition: str = "size") -> BucketPlan:
    """Partition a pytree's leaves into buckets.

    ``partition="size"`` (default) is the greedy size-balanced assignment:
    best load balance across streams, but every bucket mixes leaves from all
    over the tree, so under overlap scheduling no bucket is ready until the
    backward is nearly done. ``partition="contig"`` keeps leaves CONTIGUOUS
    in flatten (= forward use) order with size-balanced split points — the
    PyTorch-DDP bucket shape: the bucket holding the last-used leaves has
    all its cotangents early in the backward and its reduce can issue while
    earlier layers are still differentiating (see
    :func:`bucket_ready_order`).

    ``slot_align`` additionally places every leaf at an aligned offset
    *inside* its bucket buffer (zero-gap padding between slots) — the
    layout contract of the Pallas pack/unpack kernels, where one
    destination tile reads from exactly one source segment.
    """
    if slot_align is not None:
        assert align % slot_align == 0, (align, slot_align)
    if partition not in ("size", "contig"):
        raise ValueError(f"unknown partition {partition!r}")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [int(np.prod(l.shape)) if l.shape else 1 for l in leaves]
    num_buckets = max(1, min(num_buckets, len(leaves)))
    members: List[List[int]] = [[] for _ in range(num_buckets)]
    if partition == "size":
        order = sorted(range(len(leaves)), key=lambda i: -sizes[i])
        loads = [0] * num_buckets
        for i in order:
            b = loads.index(min(loads))
            members[b].append(i)
            loads[b] += sizes[i]
    else:  # contig: balanced prefix splits of the use-ordered leaf sequence
        total = sum(sizes)
        b, load = 0, 0
        for i in range(len(leaves)):
            left = len(leaves) - i  # leaves not yet placed (including i)
            if (b < num_buckets - 1 and members[b]
                    and (load >= total * (b + 1) / num_buckets
                         or left <= num_buckets - 1 - b)):
                b += 1
            members[b].append(i)
            load += sizes[i]
    buckets = []
    for bid, idxs in enumerate(members):
        idxs = sorted(idxs)
        slots, off = [], 0
        for i in idxs:
            if slot_align is not None:
                off = _round_up(off, slot_align)
            slots.append(LeafSlot(i, tuple(leaves[i].shape), leaves[i].dtype, off))
            off += sizes[i]
        buckets.append(Bucket(bid, tuple(slots), _round_up(max(off, 1), align)))
    return BucketPlan(treedef, tuple(buckets), align, slot_align)


def bucket_ready_order(plan: BucketPlan,
                       leaf_use_order: Optional[Sequence[int]] = None
                       ) -> Tuple[int, ...]:
    """Reverse-topological bucket order: buckets sorted by backward readiness.

    The backward pass produces cotangents in REVERSE forward-use order, so a
    bucket has all its cotangents once its *earliest-used* leaf has been
    differentiated. ``leaf_use_order`` lists leaf indices in forward use
    order (default: flatten order, which is how ``init_params`` trees are
    consumed). Buckets whose earliest leaf is used LATE in the forward are
    ready FIRST in the backward — they lead this order, so their reduces
    (and, for ZeRO-1, their param gathers) should be issued first.
    """
    if leaf_use_order is None:
        use = list(range(plan.num_leaves))
    else:
        if sorted(leaf_use_order) != list(range(plan.num_leaves)):
            raise ValueError("leaf_use_order must be a permutation of "
                             f"range({plan.num_leaves})")
        use = [0] * plan.num_leaves
        for pos, idx in enumerate(leaf_use_order):
            use[idx] = pos
    def earliest_use(b: Bucket) -> int:
        return min(use[s.index] for s in b.slots)
    return tuple(sorted(range(plan.num_buckets),
                        key=lambda bid: (-earliest_use(plan.buckets[bid]),
                                         bid)))


# ---------------------------------------------------------------------------
# pack / unpack — the XLA (concat-chain / slice) reference path
# ---------------------------------------------------------------------------

def pack_bucket(leaves: Sequence[jax.Array], bucket: Bucket,
                dtype=jnp.float32) -> jax.Array:
    """Pack a bucket's leaves into one flat, tile-aligned buffer."""
    parts = []
    cursor = 0
    for s in bucket.slots:
        assert s.offset >= cursor, "slots must be non-overlapping, in order"
        if s.offset > cursor:  # slot-aligned layout: zero-fill the gap
            parts.append(jnp.zeros((s.offset - cursor,), dtype=dtype))
            cursor = s.offset
        parts.append(leaves[s.index].astype(dtype).reshape(-1))
        cursor += s.size
    pad = bucket.padded_size - cursor
    if pad:
        parts.append(jnp.zeros((pad,), dtype=dtype))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_bucket(flat: jax.Array, bucket: Bucket) -> List[Tuple[int, jax.Array]]:
    """Inverse of pack: returns (leaf_index, value) pairs."""
    out = []
    for s in bucket.slots:
        piece = lax_slice(flat, s.offset, s.offset + s.size)
        out.append((s.index, piece.reshape(s.shape).astype(s.dtype)))
    return out


def lax_slice(x, start, stop):
    return jax.lax.slice_in_dim(x, start, stop, axis=0)


# ---------------------------------------------------------------------------
# persistent comm plans
# ---------------------------------------------------------------------------

class CommPlan:
    """Everything hoistable out of the traced step, built once and reused.

    Holds the ``BucketPlan``, the ``CommWorld`` with one pre-created
    CommContext per bucket (the VCI mapping), and — for the pallas pack
    path — the host-side tile index tables (arena layout, per-bucket pack
    tables, the global unpack table). Ordering tokens live in the
    ``ProgressEngine`` and are trace-local, so :meth:`runtime` returns a
    FRESH ``CommRuntime`` for each trace; sharing one across traces would
    leak tracers.
    """

    def __init__(self, plan: BucketPlan, *, num_vcis: int = 8,
                 vci_policy: str = "fcfs", progress: str = "hybrid",
                 join_every: int = 8, token_impl: str = "barrier",
                 schedule: str = "post"):
        if schedule not in ("post", "overlap"):
            raise ValueError(f"unknown schedule {schedule!r}")
        self.plan = plan
        self.world = CommWorld(num_vcis=num_vcis, policy=vci_policy)
        self.contexts: Tuple[CommContext, ...] = tuple(
            self.world.create(f"bucket{b.bid}", kind="p2p")
            for b in plan.buckets)
        self.progress = progress
        self.join_every = join_every
        self.token_impl = token_impl
        self.schedule = schedule
        self._tables = None
        self._ready_order: Optional[Tuple[int, ...]] = None

    @property
    def ready_order(self) -> Tuple[int, ...]:
        """Bucket issue order for overlap scheduling (backward readiness)."""
        if self._ready_order is None:
            self._ready_order = bucket_ready_order(self.plan)
        return self._ready_order

    def runtime(self) -> CommRuntime:
        """A fresh per-trace runtime bound to the cached world/contexts."""
        return CommRuntime(self.world, progress=self.progress,
                           join_every=self.join_every,
                           token_impl=self.token_impl)

    # -- pallas tile tables (lazy, computed once) -----------------------
    @property
    def tables(self):
        """(tile, arena_offsets, arena_size, pack_tables, unpack_table).

        ``pack_tables[b]`` maps bucket ``b``'s destination tiles to arena
        source tiles; ``unpack_table`` maps arena tiles back into the
        CONCATENATION of all reduced bucket buffers (bucket base offsets
        are the running sum of padded sizes).
        """
        if self._tables is None:
            from repro.kernels.bucket_pack import arena_layout, build_tile_tables

            plan = self.plan
            tile = plan.slot_align
            assert tile is not None, (
                "pallas pack path needs a slot-aligned plan "
                "(plan_buckets(..., slot_align=TILE))")
            n_leaves = plan.num_leaves
            sizes = [0] * n_leaves
            for b in plan.buckets:
                for s in b.slots:
                    sizes[s.index] = s.size
            arena_offs, arena_size = arena_layout(sizes, tile)
            pack_tables = []
            for b in plan.buckets:
                blk, val = build_tile_tables(
                    [arena_offs[s.index] for s in b.slots],
                    [s.offset for s in b.slots],
                    [s.size for s in b.slots], b.padded_size, tile)
                pack_tables.append((blk, val))
            bases = np.cumsum([0] + [b.padded_size for b in plan.buckets])
            src, dst, szs = [], [], []
            for bi, b in enumerate(plan.buckets):
                for s in b.slots:
                    src.append(int(bases[bi]) + s.offset)
                    dst.append(int(arena_offs[s.index]))
                    szs.append(s.size)
            unpack_table = build_tile_tables(src, dst, szs, arena_size, tile)
            self._tables = (tile, arena_offs, arena_size,
                            tuple(pack_tables), unpack_table)
        return self._tables


_PLAN_CACHE: Dict[Any, CommPlan] = {}
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "builds": 0}


def comm_plan_key(grads, *, num_streams: int, align: int,
                  slot_align: Optional[int], num_vcis: int, vci_policy: str,
                  progress: str, join_every: int, token_impl: str,
                  schedule: str = "post"):
    """Hashable cache key: tree structure + leaf shapes/dtypes + knobs."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = tuple((tuple(l.shape), jnp.dtype(l.dtype).name) for l in leaves)
    return (treedef, shapes, num_streams, align, slot_align, num_vcis,
            vci_policy, progress, join_every, token_impl, schedule)


def get_comm_plan(grads, *, num_streams: int = 8, align: int = TILE,
                  pack: str = "xla", num_vcis: int = 8,
                  vci_policy: str = "fcfs", progress: str = "hybrid",
                  join_every: int = 8, token_impl: str = "barrier",
                  schedule: str = "post",
                  persistent: bool = True) -> CommPlan:
    """Build (or fetch) the CommPlan for a gradient pytree.

    ``persistent=True`` (the fast path) caches on (treedef, shapes, knobs):
    repeated eager ``train_step`` calls and jit retraces pay the Python
    plan/world construction exactly once. ``persistent=False`` rebuilds
    from scratch every call — the seed behaviour, kept for the ablation.

    ``schedule="overlap"`` keys a separate plan whose buckets are
    CONTIGUOUS in leaf-use order (``partition="contig"``) so they become
    ready progressively during the backward — the layout
    :func:`overlap_boundaries` consumes.
    """
    slot_align = align if pack == "pallas" else None
    key = comm_plan_key(grads, num_streams=num_streams, align=align,
                        slot_align=slot_align, num_vcis=num_vcis,
                        vci_policy=vci_policy, progress=progress,
                        join_every=join_every, token_impl=token_impl,
                        schedule=schedule)
    if persistent:
        cached = _PLAN_CACHE.get(key)
        if cached is not None:
            _PLAN_CACHE_STATS["hits"] += 1
            return cached
        _PLAN_CACHE_STATS["misses"] += 1
    partition = "contig" if schedule == "overlap" else "size"
    plan = plan_buckets(grads, num_streams, align=align,
                        slot_align=slot_align, partition=partition)
    cp = CommPlan(plan, num_vcis=num_vcis, vci_policy=vci_policy,
                  progress=progress, join_every=join_every,
                  token_impl=token_impl, schedule=schedule)
    _PLAN_CACHE_STATS["builds"] += 1
    if persistent:
        _PLAN_CACHE[key] = cp
    return cp


def plan_cache_stats() -> Dict[str, int]:
    return dict(_PLAN_CACHE_STATS, size=len(_PLAN_CACHE))


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    for k in _PLAN_CACHE_STATS:
        _PLAN_CACHE_STATS[k] = 0


# ---------------------------------------------------------------------------
# the bucketed reduction itself
# ---------------------------------------------------------------------------

def _pack_bucket_dma(leaves, bucket: Bucket, dtype) -> jax.Array:
    """Non-TPU lowering of the pallas pack: one dynamic_update_slice per
    slot into the zero-initialized staging buffer — the XLA analogue of the
    kernel's per-segment DMA writes. Identical output to the kernel (and to
    ``pack_bucket``); measured ~3x faster than the concat chain on the
    8-device CPU mesh, where XLA:CPU executes each DUS as an in-place
    contiguous memcpy but pays a full materialization per concat operand."""
    buf = jnp.zeros((bucket.padded_size,), dtype)
    for s in bucket.slots:
        buf = jax.lax.dynamic_update_slice(
            buf, leaves[s.index].astype(dtype).reshape(-1), (s.offset,))
    return buf


def reduce_gradients(
    rt: CommRuntime,
    grads,
    plan: Union[BucketPlan, CommPlan],
    *,
    axis="data",
    mean: bool = True,
    staging: str = "per_vci",
    reduce_dtype=jnp.float32,
    contexts=None,
    pack: str = "xla",
    reduction: str = "all_reduce",
    output: str = "tree",
):
    """All-reduce a gradient pytree over ``axis`` on VCI streams.

    One CommContext per bucket (created here unless supplied or cached on a
    :class:`CommPlan`). Knobs (see module docstring): ``staging`` shared vs
    per-VCI buffers, ``pack`` xla-concat vs pallas tile-gather, ``reduction``
    all_reduce vs reduce_scatter+all_gather. The reduce-scatter variant
    falls back to all_reduce for any bucket whose padded size does not
    divide the axis size (never with tile alignment on 2^k-device meshes).

    ``output="shards"`` (requires ``reduction="reduce_scatter"``) stops after
    the scatter: returns ``(shards, layout)`` where ``shards[b]`` is this
    rank's float32 slice of reduced bucket ``b`` (mean already applied when
    ``mean=True``) and ``layout`` is the :class:`ShardLayout`. Every bucket
    must then divide the axis size — there is no all_reduce fallback, by
    construction the caller is a sharded optimizer that owns exactly 1/N of
    each bucket. ``reduce_dtype`` is the WIRE dtype of the scatter (bf16
    wire + fp32 shards is the mixed-precision ZeRO recipe).
    """
    if pack not in ("xla", "pallas"):
        raise ValueError(f"unknown pack impl {pack!r}")
    if reduction not in ("all_reduce", "reduce_scatter"):
        raise ValueError(f"unknown reduction {reduction!r}")
    if output not in ("tree", "shards"):
        raise ValueError(f"unknown output {output!r}")
    if output == "shards" and reduction != "reduce_scatter":
        raise ValueError("output='shards' requires reduction='reduce_scatter'")

    comm_plan = plan if isinstance(plan, CommPlan) else None
    bplan: BucketPlan = comm_plan.plan if comm_plan is not None else plan
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if contexts is None:
        if comm_plan is not None:
            contexts = comm_plan.contexts
        else:
            contexts = [rt.world.create(kind="p2p") for _ in bplan.buckets]

    # ---- pack --------------------------------------------------------------
    on_tpu = jax.default_backend() == "tpu"
    if pack == "pallas" and on_tpu:
        from repro.kernels.bucket_pack import (arena_from_leaves,
                                               bucket_pack_pallas)

        if comm_plan is not None:
            tile, arena_offs, arena_size, pack_tables, unpack_table = \
                comm_plan.tables
        else:
            tile, arena_offs, arena_size, pack_tables, unpack_table = \
                CommPlan(bplan, num_vcis=1).tables
        arena, _ = arena_from_leaves(leaves, tile=tile, dtype=reduce_dtype)
        assert arena.shape[0] == arena_size, (arena.shape, arena_size)
        packed = [bucket_pack_pallas(arena, jnp.asarray(t[0]),
                                     jnp.asarray(t[1]), b.padded_size,
                                     tile=tile)
                  for t, b in zip(pack_tables, bplan.buckets)]
    elif pack == "pallas":
        # Non-TPU lowering of the same layout contract: per-slot DMA writes
        # (dynamic_update_slice) instead of the tile-gather kernel.
        packed = [_pack_bucket_dma(leaves, b, reduce_dtype)
                  for b in bplan.buckets]
    else:
        packed = [pack_bucket(leaves, b, dtype=reduce_dtype)
                  for b in bplan.buckets]

    if staging == "shared":
        # One staging array; each bucket is inserted then re-extracted,
        # threading a value dependency through every stream (serialized).
        stage = jnp.zeros((bplan.total_padded,), dtype=reduce_dtype)
        offs = np.cumsum([0] + [b.padded_size for b in bplan.buckets])
        for i, p in enumerate(packed):
            stage = jax.lax.dynamic_update_slice(stage, p, (int(offs[i]),))
        packed = [jax.lax.dynamic_slice(stage, (int(offs[i]),),
                                        (bplan.buckets[i].padded_size,))
                  for i in range(len(packed))]

    # ---- reduce ------------------------------------------------------------
    n = _axis_size(axis)

    if output == "shards":
        layout = ShardLayout(bplan, n)  # raises on indivisible buckets
        shards = []
        for p, ctx in zip(packed, contexts):
            shard = rt.reduce_scatter(p, ctx, axis=axis).astype(jnp.float32)
            shards.append(shard / n if mean else shard)
        return shards, layout

    reduced = [_reduce_flat(rt, ctx, p, axis=axis, n=n, mean=mean,
                            reduction=reduction, padded=b.padded_size)
               for p, ctx, b in zip(packed, contexts, bplan.buckets)]

    # ---- unpack ------------------------------------------------------------
    out_leaves: List[Optional[jax.Array]] = [None] * len(leaves)
    if pack == "pallas" and on_tpu:
        from repro.kernels.bucket_pack import bucket_unpack_pallas

        reduced_all = (jnp.concatenate(reduced) if len(reduced) > 1
                       else reduced[0])
        out_arena = bucket_unpack_pallas(
            reduced_all, jnp.asarray(unpack_table[0]),
            jnp.asarray(unpack_table[1]), arena_size, tile=tile)
        sizes = [int(np.prod(l.shape)) if l.shape else 1 for l in leaves]
        for i, leaf in enumerate(leaves):
            off = int(arena_offs[i])
            piece = lax_slice(out_arena, off, off + sizes[i])
            out_leaves[i] = piece.reshape(leaf.shape).astype(leaf.dtype)
    else:
        # slice-per-slot unpack (a contiguous read per leaf; already the
        # fastest form on CPU — see BENCH_bucket_path.json)
        for flat, b in zip(reduced, bplan.buckets):
            for idx, val in unpack_bucket(flat, b):
                out_leaves[idx] = val
    assert all(v is not None for v in out_leaves)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def _axis_size(axis) -> int:
    from repro.compat import axis_size
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= axis_size(a)
        return n
    return axis_size(axis)


# ---------------------------------------------------------------------------
# bucket-ready overlap scheduling (schedule="overlap")
# ---------------------------------------------------------------------------

def _reduce_flat(rt: CommRuntime, ctx, flat, *, axis, n: int, mean: bool,
                 reduction: str, padded: int):
    """One bucket buffer's reduction: reduce_scatter + all_gather when the
    bucket divides the axis, else all_reduce. SHARED by the post-pass
    (``reduce_gradients``) and the overlap boundaries, so the two schedules
    stay op-for-op identical by construction."""
    if reduction == "reduce_scatter" and padded % n == 0:
        shard = rt.reduce_scatter(flat, ctx, axis=axis)
        if mean:
            shard = shard / n
        return rt.all_gather(shard, ctx, axis=axis)
    r = rt.all_reduce(flat, ctx, axis=axis)
    return r / n if mean else r


def _bucket_boundary(cp: CommPlan, bucket: Bucket, ctx, *, axis, n: int,
                     mean: bool, pack: str, reduction: str, reduce_dtype,
                     accum_steps: int, shards_mode: bool):
    """A ``custom_vjp`` identity over one bucket's leaves whose BACKWARD
    issues that bucket's reduction on its VCI stream.

    Forward: ``boundary(leaves, tap, carry) -> leaves`` (identity; ``tap``
    and ``carry`` do not touch the forward values). Backward: the incoming
    cotangents ARE the bucket's gradients, available the moment AD reaches
    this bucket's leaves — reverse-topologically *before* earlier layers
    finish differentiating — so the pack + reduce emitted here carries a
    data dependency on this bucket alone and XLA may run it concurrently
    with the rest of the backward. Each boundary mints a FRESH runtime:
    per-bucket (per-stream) ordering is exactly what makes early issue
    legal (MPIX-stream semantics); cross-stream joins would re-serialize
    the very overlap being created.

    ``carry`` (microbatch accumulation) holds the mean-scaled gradient sum
    of all earlier microbatches; the backward folds the final microbatch in
    with the same ``carry + ct/accum_steps`` arithmetic the post-schedule
    scan uses, so numerics match bit-for-bit. ``tap`` is only used in
    ``shards_mode``: the reduce_scatter shard leaves the backward as the
    tap's "gradient" (the ZeRO-1 side channel — cotangent shapes must match
    their primals, so the 1/N shard cannot ride out on the params).

    ``pack="pallas"`` here means the SLOT-ALIGNED LAYOUT with per-slot DUS
    writes on every backend — the boundary never dispatches the fused
    ``bucket_pack_pallas`` tile-gather kernel, even on TPU, because the
    kernel's tables index one global arena spanning ALL leaves while a
    boundary sees only its own bucket's cotangents. Per-bucket tile tables
    would lift this (ROADMAP); until then overlap-on-TPU pays the DUS
    lowering where the post schedule pays the fused kernel.
    """
    pack_dma = pack == "pallas"

    def _total(carry, cts):
        if carry is None:
            return list(cts)
        return [(c + ct.astype(jnp.float32) / accum_steps).astype(s.dtype)
                for c, ct, s in zip(carry, cts, bucket.slots)]

    def _pack(vals):
        full: List[Optional[jax.Array]] = \
            [None] * (max(s.index for s in bucket.slots) + 1)
        for s, v in zip(bucket.slots, vals):
            full[s.index] = v
        if pack_dma:
            return _pack_bucket_dma(full, bucket, reduce_dtype)
        return pack_bucket(full, bucket, dtype=reduce_dtype)

    @jax.custom_vjp
    def boundary(leaves, tap, carry):
        return leaves

    def fwd(leaves, tap, carry):
        return leaves, carry

    def bwd(carry, cts):
        rt = cp.runtime()
        flat = _pack(_total(carry, cts))
        carry_ct = None if carry is None else \
            tuple(jnp.zeros_like(c) for c in carry)
        if shards_mode:
            shard = rt.reduce_scatter(flat, ctx, axis=axis) \
                .astype(jnp.float32)
            if mean:
                shard = shard / n
            zero_cts = tuple(jnp.zeros(s.shape, s.dtype)
                             for s in bucket.slots)
            return zero_cts, shard, carry_ct
        reduced = _reduce_flat(rt, ctx, flat, axis=axis, n=n, mean=mean,
                               reduction=reduction, padded=bucket.padded_size)
        by_index = dict(unpack_bucket(reduced, bucket))
        return (tuple(by_index[s.index] for s in bucket.slots), None,
                carry_ct)

    boundary.defvjp(fwd, bwd)
    return boundary


def overlap_boundaries(
    cp: CommPlan,
    params,
    *,
    axis,
    taps: Optional[Sequence[jax.Array]] = None,
    carry=None,
    accum_steps: int = 1,
    mean: bool = True,
    pack: str = "xla",
    reduction: str = "all_reduce",
    reduce_dtype=jnp.float32,
):
    """Wrap ``params`` so every bucket's gradient reduce is issued INSIDE
    the backward, on the bucket's dedicated VCI stream, as soon as its
    cotangents exist (bucket-ready hooks, PyTorch-DDP style).

    Returns the wrapped parameter tree (forward values are unchanged).
    Differentiating a loss of the wrapped tree w.r.t. ``params`` yields the
    *already-reduced* mean gradients — ``reduce_gradients`` must NOT run
    again. With ``taps`` (ZeRO-1 mode: one zero-initialized f32 array of
    shard size per bucket, see :class:`ShardLayout`), the params' gradients
    are zeros and each tap's gradient is instead this rank's mean-reduced
    ``reduce_scatter`` shard of its bucket (``reduce_dtype`` = wire dtype),
    exactly what ``reduce_gradients(..., output="shards")`` returns post-hoc.

    ``carry`` threads microbatch accumulation through the boundary: pass
    the mean-scaled gradient sum of all *earlier* microbatches (a tree like
    ``params``) plus ``accum_steps``, and differentiate only the LAST
    microbatch's loss — the backward folds the carry in before reducing, so
    one set of reduces per step, not per microbatch.
    """
    bplan = cp.plan
    leaves, treedef = jax.tree_util.tree_flatten(params)
    if treedef != bplan.treedef:
        raise ValueError("params tree does not match the CommPlan's tree")
    shards_mode = taps is not None
    if shards_mode:
        if len(taps) != bplan.num_buckets:
            raise ValueError(f"need one tap per bucket "
                             f"({bplan.num_buckets}), got {len(taps)}")
    carry_leaves = None
    if carry is not None:
        carry_leaves = treedef.flatten_up_to(carry)
    n = _axis_size(axis)
    if shards_mode:
        ShardLayout(bplan, n)  # raises on indivisible buckets
    out: List[Optional[jax.Array]] = [None] * len(leaves)
    for b in bplan.buckets:
        boundary = _bucket_boundary(
            cp, b, cp.contexts[b.bid], axis=axis, n=n, mean=mean, pack=pack,
            reduction=reduction, reduce_dtype=reduce_dtype,
            accum_steps=accum_steps, shards_mode=shards_mode)
        b_leaves = tuple(leaves[s.index] for s in b.slots)
        b_carry = None if carry_leaves is None else \
            tuple(carry_leaves[s.index] for s in b.slots)
        tap = taps[b.bid] if shards_mode else None
        wrapped = boundary(b_leaves, tap, b_carry)
        for s, w in zip(b.slots, wrapped):
            out[s.index] = w
    assert all(v is not None for v in out)
    return jax.tree_util.tree_unflatten(treedef, out)


def all_gather_shards(rt: CommRuntime, shards: Sequence[jax.Array],
                      plan: Union[BucketPlan, CommPlan], *, axis,
                      contexts=None, wire_dtype=None,
                      order: Optional[Sequence[int]] = None):
    """Rebuild the full pytree from per-rank bucket shards (ZeRO-1 step 3).

    The inverse of ``reduce_gradients(..., output="shards")`` composed with
    ``unpack``: each bucket's local shard is all-gathered on the SAME
    CommContext/VCI its reduce_scatter used (when ``plan`` is the CommPlan),
    re-assembling the ``padded_size`` buffer, which is then unpacked into
    leaves (cast to each LeafSlot's dtype). ``wire_dtype`` sets the gather
    payload dtype — param-dtype wire (e.g. bf16) halves the gather bytes
    and is lossless when every leaf shares that dtype. ``order`` sets the
    per-bucket ISSUE order (default: bucket id); the overlap trainer passes
    ``CommPlan.ready_order`` so first-ready buckets' gathers chain first on
    their streams and pipeline behind later buckets' reduces.
    """
    comm_plan = plan if isinstance(plan, CommPlan) else None
    bplan: BucketPlan = comm_plan.plan if comm_plan is not None else plan
    if contexts is None:
        if comm_plan is not None:
            contexts = comm_plan.contexts
        else:
            contexts = [rt.world.create(kind="p2p") for _ in bplan.buckets]
    if order is None:
        order = range(bplan.num_buckets)
    out_leaves: List[Optional[jax.Array]] = [None] * bplan.num_leaves
    for bid in order:
        shard, ctx, b = shards[bid], contexts[bid], bplan.buckets[bid]
        if wire_dtype is not None:
            shard = shard.astype(wire_dtype)
        flat = rt.all_gather(shard, ctx, axis=axis)
        for idx, val in unpack_bucket(flat, b):
            out_leaves[idx] = val
    assert all(v is not None for v in out_leaves)
    return jax.tree_util.tree_unflatten(bplan.treedef, out_leaves)
