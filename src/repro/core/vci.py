"""Virtual Communication Interfaces (paper §4.2).

A VCI is an abstract, library-internal representation of an independent
communication stream. On the paper's hardware a VCI binds to a NIC context
(OFI endpoint / UCP worker + QP); on TPU/XLA a VCI is an independently
schedulable chain of collective ops — operations on the same VCI are
FIFO-ordered through an *ordering token* (see ``repro.core.progress``),
operations on different VCIs carry no mutual dependency, so XLA may execute
them concurrently and overlap them with compute.

The pool semantics follow the paper exactly:

* the pool holds ``num_vcis`` interfaces (hardware contexts are limited —
  e.g. 160 on Intel OPA; ICI collective channels are bounded by scheduler
  resources);
* every new :class:`~repro.core.comm.CommContext` (communicator/window
  analogue) acquires a VCI at creation time;
* when the pool is exhausted the context falls back to the **fallback VCI**
  (the one owned by COMM_WORLD in the paper) — contexts sharing a VCI share
  its ordering token and therefore serialize, which is precisely the
  "mismatch in expected mapping" effect of Fig. 17;
* freeing a context returns its VCI to the pool.

Assignment policies:

* ``fcfs``        — the paper's first-come-first-served pool.
* ``round_robin`` — CRI-style cycling (Patinyasakdikul et al., compared in
                    §8.2); never exhausts, but may co-locate hot contexts.
* ``hash``        — stateless ``hash(ctx_name) % num_vcis``.
* ``hinted``      — the paper's §5.2 suggestion: the user hints which
                    contexts need dedicated VCIs; hinted contexts get
                    dedicated interfaces first, unhinted ones share the
                    fallback.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

POLICIES = ("fcfs", "round_robin", "hash", "hinted")


@dataclass(frozen=True)
class VCI:
    """One virtual communication interface."""

    index: int

    @property
    def name(self) -> str:
        return f"vci{self.index}"


@dataclass
class VCIStats:
    """Pool accounting.

    ``fallback_hits`` counts only *genuine* fallback events — pool
    exhaustion or an explicit ``hint="shared"`` — not every assignment that
    happens to land on VCI 0 (a ``hash`` policy mapping a context to index 0
    is a normal assignment, not a degradation). ``per_vci_contexts`` tracks
    LIVE contexts: releases decrement it, so ``max_contexts_per_vci``
    reflects the current worst-case sharing, which is what the
    mapping-mismatch benchmark correlates with serialization.
    """

    acquires: int = 0
    fallback_hits: int = 0
    releases: int = 0
    per_vci_contexts: Dict[int, int] = field(default_factory=dict)

    def record(self, idx: int, fallback: bool) -> None:
        self.acquires += 1
        self.fallback_hits += int(fallback)
        self.per_vci_contexts[idx] = self.per_vci_contexts.get(idx, 0) + 1

    def record_release(self, idx: int) -> None:
        self.releases += 1
        live = self.per_vci_contexts.get(idx, 0) - 1
        if live > 0:
            self.per_vci_contexts[idx] = live
        else:
            self.per_vci_contexts.pop(idx, None)

    @property
    def max_contexts_per_vci(self) -> int:
        return max(self.per_vci_contexts.values(), default=0)


class VCIPool:
    """Pool of VCIs inside a single process (paper §4.2, "VCI pool design")."""

    FALLBACK = 0  # the COMM_WORLD VCI

    def __init__(self, num_vcis: int = 8, policy: str = "fcfs"):
        if num_vcis < 1:
            raise ValueError("need at least the fallback VCI")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.num_vcis = num_vcis
        self.policy = policy
        self.stats = VCIStats()
        # VCI 0 is the fallback (assigned to COMM_WORLD); it is never free.
        self._free: List[int] = list(range(num_vcis - 1, 0, -1))
        self._assignment: Dict[str, int] = {}
        self._rr_next = 1 if num_vcis > 1 else 0

    # ------------------------------------------------------------------
    def acquire(self, ctx_name: str, hint: Optional[str] = None) -> VCI:
        """Assign a VCI to a newly created context.

        ``hint`` mirrors the paper's proposed info hints: ``"dedicated"``
        requests an exclusive interface (hinted policy), ``"shared"``
        deliberately takes the fallback.
        """
        if ctx_name in self._assignment:
            raise KeyError(f"context {ctx_name!r} already holds a VCI")
        idx, fallback = self._select(ctx_name, hint)
        self._assignment[ctx_name] = idx
        self.stats.record(idx, fallback=fallback)
        return VCI(idx)

    def release(self, ctx_name: str) -> None:
        idx = self._assignment.pop(ctx_name)
        self.stats.record_release(idx)
        if idx != self.FALLBACK and self.policy in ("fcfs", "hinted"):
            self._free.append(idx)

    def lookup(self, ctx_name: str) -> Optional[VCI]:
        idx = self._assignment.get(ctx_name)
        return None if idx is None else VCI(idx)

    @property
    def active(self) -> int:
        return len(self._assignment)

    # ------------------------------------------------------------------
    def _select(self, ctx_name: str, hint: Optional[str]) -> Tuple[int, bool]:
        """Returns ``(index, fallback)``.

        ``fallback`` is True only on a genuine fallback event: explicit
        ``hint="shared"`` or pool exhaustion. A ``hash`` assignment that
        happens to land on index 0 — or a ``hinted``-policy context that
        never asked for a dedicated interface — is a normal assignment and
        must not inflate ``fallback_hits`` (that miscount skewed the
        mapping-mismatch benchmark's exhaustion curve).
        """
        if hint == "shared":
            return self.FALLBACK, True
        if self.num_vcis == 1:
            # only the fallback exists: every assignment shares COMM_WORLD's
            # stream — a genuine (permanent) exhaustion, for EVERY policy
            return self.FALLBACK, True
        if self.policy == "fcfs":
            if self._free:
                return self._free.pop(), False
            return self.FALLBACK, True
        if self.policy == "round_robin":
            idx = self._rr_next
            self._rr_next += 1
            if self._rr_next >= self.num_vcis:
                self._rr_next = 1
            return idx, False
        if self.policy == "hash":
            h = int.from_bytes(
                hashlib.blake2s(ctx_name.encode()).digest()[:4], "little")
            return h % self.num_vcis, False
        if self.policy == "hinted":
            if hint == "dedicated" and self._free:
                return self._free.pop(), False
            if hint == "dedicated":
                return self.FALLBACK, True  # exhausted, same as fcfs
            # unhinted contexts share the fallback by design, not exhaustion
            return self.FALLBACK, False
        raise AssertionError(self.policy)
