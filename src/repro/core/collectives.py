"""Stream-tagged collectives: the VCI-aware communication runtime (§4.3).

Used inside ``shard_map`` regions (manual mesh axes). Every operation is
issued on a :class:`~repro.core.comm.CommContext`; the runtime

1. *enters* the context's VCI stream — chains the payload on the stream's
   ordering token (critical-section acquisition),
2. issues the underlying ``jax.lax`` collective,
3. *completes* — advances the stream token past the result (release), and
4. under ``hybrid`` progress performs a global round every K issues.

Operations on different VCIs carry no mutual dependency: XLA is free to
schedule them concurrently — the TPU realization of the paper's parallel
communication streams. Operations landing on the same VCI (same context, or
distinct contexts that collided in the pool — Fig. 17) serialize through the
shared token.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence, Tuple, Union

import jax
from jax import lax

from repro.core.comm import CommContext, CommWorld
from repro.core.progress import ProgressEngine

AxisName = Union[str, Tuple[str, ...]]


@dataclass(frozen=True)
class Request:
    """Nonblocking-operation handle (MPI_Request analogue)."""

    value: jax.Array
    ctx: CommContext


class CommRuntime:
    """Trace-time communication runtime bound to a CommWorld's contexts."""

    def __init__(
        self,
        world: Optional[CommWorld] = None,
        *,
        progress: str = "hybrid",
        join_every: int = 8,
        token_impl: str = "barrier",
    ):
        self.world = world or CommWorld()
        self.engine = ProgressEngine(
            mode=progress, join_every=join_every, token_impl=token_impl)

    # -- plumbing ------------------------------------------------------
    def _issue(self, ctx: CommContext, x, op, *, chain: bool = True):
        if chain:
            x = self.engine.enter(ctx.vci.index, x)
        out = op(x)
        self.engine.complete(ctx.vci.index, out)
        return out

    # -- two-sided (communicator) ops -----------------------------------
    def sendrecv(self, x, ctx: CommContext, *, axis: AxisName,
                 perm: Sequence[Tuple[int, int]]) -> jax.Array:
        """Pairwise exchange (Isend/Irecv pair) along ``axis``: each (src,
        dst) in ``perm`` ships this shard's ``x`` from src to dst."""
        return self._issue(ctx, x, partial(lax.ppermute, axis_name=axis, perm=perm))

    def isend_recv(self, x, ctx: CommContext, *, axis: AxisName,
                   perm: Sequence[Tuple[int, int]]) -> Request:
        return Request(self.sendrecv(x, ctx, axis=axis, perm=perm), ctx)

    def wait(self, req: Request) -> jax.Array:
        """MPI_Wait: consume the value ordered after its stream token."""
        return self.engine._after(req.value, self.engine.token(req.ctx.vci.index))

    def all_reduce(self, x, ctx: CommContext, *, axis: AxisName) -> jax.Array:
        return self._issue(ctx, x, partial(lax.psum, axis_name=axis))

    def all_gather(self, x, ctx: CommContext, *, axis: AxisName,
                   gather_axis: int = 0, tiled: bool = True) -> jax.Array:
        return self._issue(
            ctx, x, partial(lax.all_gather, axis_name=axis, axis=gather_axis,
                            tiled=tiled))

    def reduce_scatter(self, x, ctx: CommContext, *, axis: AxisName,
                       scatter_axis: int = 0) -> jax.Array:
        return self._issue(
            ctx, x, partial(lax.psum_scatter, axis_name=axis,
                            scatter_dimension=scatter_axis, tiled=True))

    def all_to_all(self, x, ctx: CommContext, *, axis: AxisName,
                   split_axis: int, concat_axis: int) -> jax.Array:
        return self._issue(
            ctx, x, partial(lax.all_to_all, axis_name=axis,
                            split_axis=split_axis, concat_axis=concat_axis,
                            tiled=True))

    # -- one-sided (window) ops -----------------------------------------
    def get(self, x, ctx: CommContext, *, axis: AxisName,
            perm: Sequence[Tuple[int, int]]) -> jax.Array:
        """MPI_Get analogue: fetch the owner's shard (hardware-progressed on
        TPU ICI, like the paper's Mellanox case). Get/Put carry no matching
        order, so unordered windows issue them un-chained."""
        if ctx.kind != "rma":
            raise ValueError("get() requires an rma context (window)")
        op = partial(lax.ppermute, axis_name=axis, perm=perm)
        return self._issue(ctx, x, op, chain=ctx.ordered)

    def put(self, x, ctx: CommContext, *, axis: AxisName,
            perm: Sequence[Tuple[int, int]]) -> jax.Array:
        if ctx.kind != "rma":
            raise ValueError("put() requires an rma context (window)")
        op = partial(lax.ppermute, axis_name=axis, perm=perm)
        return self._issue(ctx, x, op, chain=ctx.ordered)

    def accumulate(self, x, ctx: CommContext, *, axis: AxisName) -> jax.Array:
        """MPI_Accumulate analogue: commutative reduction into a window.

        Default ordering ("rar") chains accumulates on the window's stream —
        MPI-3.1 requires program order for same-source/same-location
        accumulates (§2.2). With ``accumulate_ordering="none"`` (the §6.3
        hint) accumulates are issued UN-chained and may proceed in parallel —
        restoring endpoint-equivalent performance for BSPMM.
        """
        if ctx.kind != "rma":
            raise ValueError("accumulate() requires an rma context (window)")
        chain = ctx.accumulate_ordering != "none"
        return self._issue(ctx, x, partial(lax.psum, axis_name=axis), chain=chain)

    # -- synchronization ------------------------------------------------
    def flush(self, x, ctx: CommContext):
        """MPI_Win_flush: order ``x`` after the window's outstanding ops.

        Completion of a flush may require *other* streams to progress
        (Fig. 9's RMA deadlock): under ``hybrid`` progress the engine's
        periodic global rounds provide that; under pure ``per_vci`` progress
        this orders only on the window's own stream — fast, and exactly as
        starvation-prone as the paper warns.
        """
        return self.engine._after(x, self.engine.token(ctx.vci.index))

    def barrier(self, x):
        """MPI_Barrier-ish: order ``x`` after ALL streams (global progress)."""
        self.engine.global_round()
        return self.engine.drain(x)
