"""repro.core — the paper's contribution: VCIs for JAX/TPU.

Public API:
    VCIPool, VCI              — the interface pool (paper §4.2)
    CommWorld, CommContext    — communicator/window analogues (§2)
    CommRuntime, Request      — stream-tagged collectives (§4.3)
    ProgressEngine            — global | per_vci | hybrid progress (§4.1/4.3)
    plan_buckets, reduce_gradients — gradient→VCI bucketing (training integration)
    CommPlan, get_comm_plan   — persistent comm plans (the fast path):
                                cached BucketPlan + CommWorld + contexts +
                                pallas pack tables per (treedef, shapes, knobs)
"""

from repro.core.bucketing import (
    Bucket,
    BucketPlan,
    CommPlan,
    ShardLayout,
    TILE,
    all_gather_shards,
    bucket_ready_order,
    comm_plan_key,
    get_comm_plan,
    overlap_boundaries,
    pack_bucket,
    plan_buckets,
    plan_cache_clear,
    plan_cache_stats,
    reduce_gradients,
    unpack_bucket,
)
from repro.core.collectives import CommRuntime, Request
from repro.core.comm import CommContext, CommWorld
from repro.core.progress import (
    PROGRESS_MODES,
    ProgressEngine,
    after,
    fresh_token,
    join_tokens,
    token_after,
)
from repro.core.vci import POLICIES, VCI, VCIPool

__all__ = [
    "Bucket", "BucketPlan", "CommPlan", "ShardLayout", "TILE",
    "all_gather_shards", "bucket_ready_order", "comm_plan_key",
    "get_comm_plan", "overlap_boundaries",
    "pack_bucket", "plan_buckets", "plan_cache_clear",
    "plan_cache_stats", "reduce_gradients", "unpack_bucket", "CommRuntime",
    "Request", "CommContext", "CommWorld", "PROGRESS_MODES", "ProgressEngine",
    "after", "fresh_token", "join_tokens", "token_after", "POLICIES", "VCI",
    "VCIPool",
]
