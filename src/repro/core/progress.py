"""Ordering tokens and progress models (paper §4.1, §4.3).

MPI's thread-safety problem translates to XLA as a *scheduling-freedom*
problem: which communication ops may the compiler reorder, interleave, and
overlap? A critical section forbids reordering of the ops it guards; we
reproduce that with **ordering tokens** threaded through
``jax.lax.optimization_barrier`` — a zero-copy HLO construct that creates a
scheduling dependency without moving payload bytes.

* ``global``   — ONE token guards every communication op: the paper's global
                 critical section. All comm serializes, nothing overlaps.
* ``per_vci``  — one token per VCI: the paper's per-VCI locks with *pure*
                 per-VCI progress. Fastest, but provides no cross-stream
                 completion guarantee — the analogue of the Fig. 9 deadlock
                 is unbounded completion skew between streams.
* ``hybrid``   — per-VCI tokens plus a *global progress round* (a join of
                 all stream tokens) every ``join_every`` issued operations:
                 the paper's correct-and-fast hybrid model (§4.3).

The token mechanics:

``after(x, tok)``      — returns ``x`` such that every consumer of the result
                         is scheduled after ``tok`` is available.
``token_after(tok,x)`` — returns a new token that becomes available only
                         after ``x`` is computed.

Both are a single ``optimization_barrier`` over a tuple: the barrier
instruction consumes all operands and produces all results, so each result
transitively depends on every operand.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PROGRESS_MODES = ("global", "per_vci", "hybrid")
TOKEN_IMPLS = ("barrier", "data")

GLOBAL_STREAM = -1  # token key used by the `global` mode


def fresh_token() -> jax.Array:
    """A new, dependency-free ordering token (trace-time constant)."""
    return jnp.zeros((), dtype=jnp.float32)


def after(x, token: jax.Array):
    """Order: ``x``'s consumers run after ``token`` is available."""
    x, _ = lax.optimization_barrier((x, token))
    return x


def token_after(token: jax.Array, x) -> jax.Array:
    """A token that completes only after ``x`` (and ``token``)."""
    token, _ = lax.optimization_barrier((token, x))
    return token


# --- "data" token impl -------------------------------------------------------
# XLA's CPU pipeline elides optimization-barriers before the collective
# combiner/scheduler run, erasing the stream structure we are studying. The
# "data" implementation instead threads the dependency through payload
# values: the token is ``first_element * 0.0`` of the guarded result (XLA
# cannot fold float ``x*0`` because of NaN/Inf semantics) and is *added* to
# the next payload. Numerically a no-op for finite values; structurally an
# un-removable dependency edge. Used by the CPU wall-clock benchmarks;
# ``barrier`` remains the default for TPU-target lowering (zero-copy).

def after_data(x, token: jax.Array):
    return jax.tree_util.tree_map(lambda a: a + token.astype(a.dtype), x)


def token_after_data(token: jax.Array, x) -> jax.Array:
    leaf = jax.tree_util.tree_leaves(x)[0]
    probe = leaf.reshape(-1)[0].astype(jnp.float32) * 0.0
    return token + probe


def join_tokens(tokens: Tuple[jax.Array, ...]) -> Tuple[jax.Array, ...]:
    """Global progress round: every returned token depends on all inputs."""
    if len(tokens) <= 1:
        return tuple(tokens)
    return tuple(lax.optimization_barrier(tuple(tokens)))


@dataclass
class ProgressEngine:
    """Trace-time token bookkeeping for one traced step.

    Mirrors the MPICH progress engine: each issued operation enters the
    critical section of its stream (is chained on the stream token), and on
    completion updates that token. ``hybrid`` additionally performs one
    global round every ``join_every`` per-stream issues — the paper performs
    one round of global progress after a number of unsuccessful per-VCI
    polls; trace-time op count is the static analogue of poll count.
    """

    mode: str = "hybrid"
    join_every: int = 8
    token_impl: str = "barrier"   # "barrier" (TPU-faithful) | "data" (CPU-proof)
    _tokens: Dict[int, jax.Array] = field(default_factory=dict)
    _issued_since_join: int = 0
    issued: int = 0
    joins: int = 0

    def __post_init__(self):
        if self.mode not in PROGRESS_MODES:
            raise ValueError(f"mode {self.mode!r} not in {PROGRESS_MODES}")
        if self.token_impl not in TOKEN_IMPLS:
            raise ValueError(f"token_impl {self.token_impl!r} not in {TOKEN_IMPLS}")

    def _after(self, x, token):
        return after_data(x, token) if self.token_impl == "data" else after(x, token)

    def _token_after(self, token, x):
        if self.token_impl == "data":
            return token_after_data(token, x)
        return token_after(token, x)

    # ------------------------------------------------------------------
    def _key(self, vci_index: int) -> int:
        return GLOBAL_STREAM if self.mode == "global" else vci_index

    def token(self, vci_index: int) -> jax.Array:
        key = self._key(vci_index)
        if key not in self._tokens:
            self._tokens[key] = fresh_token()
        return self._tokens[key]

    def enter(self, vci_index: int, payload):
        """Chain ``payload`` on the stream's token (lock acquisition)."""
        return self._after(payload, self.token(vci_index))

    def complete(self, vci_index: int, result) -> None:
        """Update the stream token after ``result`` (lock release)."""
        key = self._key(vci_index)
        self._tokens[key] = self._token_after(self.token(vci_index), result)
        self.issued += 1
        self._issued_since_join += 1
        if self.mode == "hybrid" and self._issued_since_join >= self.join_every:
            self.global_round()

    def global_round(self) -> None:
        """Join every live stream token (the hybrid global-progress round)."""
        keys = sorted(self._tokens)
        if self.token_impl == "data":
            s = sum((self._tokens[k] for k in keys), start=fresh_token())
            for k in keys:
                self._tokens[k] = s
        else:
            joined = join_tokens(tuple(self._tokens[k] for k in keys))
            for k, t in zip(keys, joined):
                self._tokens[k] = t
        self._issued_since_join = 0
        self.joins += 1

    def drain(self, x):
        """Order ``x`` after ALL outstanding streams (MPI_Finalize/step end).

        Without this, dead-code elimination could drop an un-consumed
        stream's collectives entirely — the trace-time equivalent of exiting
        before completing outstanding requests.
        """
        if not self._tokens:
            return x
        self.global_round()
        any_key = next(iter(self._tokens))
        return self._after(x, self._tokens[any_key])
