from repro.models.transformer import (
    Model,
    init_params,
)

__all__ = ["Model", "init_params"]
