"""Attention: GQA/MQA, causal + sliding-window masks, KV-cache decode.

Two execution paths:

* the XLA path (below) — used for CPU smoke tests and for every dry-run
  compile (Pallas does not lower to the CPU backend);
* the Pallas path (``repro.kernels.ops.flash_attention``) — the TPU-target
  kernel, numerically validated against ``repro.kernels.ref`` in tests; the
  model selects it with ``use_pallas=True`` on TPU.

Decode supports two cache layouts:

* full cache ``(B, S_max, KV, hd)`` with a write cursor;
* ring cache ``(B, W, KV, hd)`` for sliding-window archs — O(W) memory at
  any context length, which is what qualifies dense archs for ``long_500k``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B,S,KV,hd) -> (B,S,KV*n_rep,hd) for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


def causal_mask(q_len: int, kv_len: int, *, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """(q_len, kv_len) bool mask. ``window`` adds the sliding-window band."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    m = k_pos <= q_pos
    if window is not None:
        m &= k_pos > q_pos - window
    return m


def attention(cfg: ModelConfig, q, k, v, *, q_offset: int = 0,
              mask: Optional[jax.Array] = None,
              start: Optional[jax.Array] = None) -> jax.Array:
    """Full (prefill/train) attention. q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).

    ``start`` — (B,) int32 left-pad lengths — masks each row's pad prefix
    (key positions ``< start[b]``) so mixed-length prompts prefill exactly
    as they would alone. ``mask`` may be (Sq, Skv) shared or (B, Sq, Skv)
    per-row.
    """
    b, sq, h, hd = q.shape
    n_rep = h // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is None:
        mask = causal_mask(sq, k.shape[1], window=cfg.sliding_window,
                           q_offset=q_offset)
    if start is not None:
        pad_ok = jnp.arange(k.shape[1])[None, :] >= start[:, None]  # (B,Skv)
        mask = (mask[None] if mask.ndim == 2 else mask) & pad_ok[:, None, :]
    logits = jnp.where(mask[None, None] if mask.ndim == 2 else mask[:, None],
                       logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class KVCache:
    """KV cache; ``ring`` is static metadata (not a traced leaf) so caches
    can be scanned over the layer axis."""

    def __init__(self, k, v, length, ring: bool = False):
        self.k = k            # (B, S_cache, KV, hd) — S_cache = S_max or W
        self.v = v
        self.length = length  # () int32: tokens written so far (absolute)
        self.ring = bool(ring)

    def tree_flatten(self):
        return (self.k, self.v, self.length), self.ring

    @classmethod
    def tree_unflatten(cls, ring, children):
        return cls(*children, ring=ring)

    @classmethod
    def init(cls, cfg: ModelConfig, batch: int, max_len: int,
             dtype=jnp.bfloat16) -> "KVCache":
        w = cfg.sliding_window
        s = min(max_len, w) if (w is not None and w < max_len) else max_len
        kvh = cfg.num_kv_heads * max(1, cfg.decode_kv_expand)
        shape = (batch, s, kvh, cfg.head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32), ring=bool(w is not None and w < max_len))


def _expand_to_cache(cache: KVCache, k_new):
    """OPT(decode_cache): the cache may store each KV head ``e`` times (so
    stored heads == TP degree and attention shards losslessly); expand the
    incoming head dim to match."""
    kv_c, kv_n = cache.k.shape[2], k_new.shape[2]
    if kv_c == kv_n:
        return k_new
    assert kv_c % kv_n == 0, (kv_c, kv_n)
    return jnp.repeat(k_new, kv_c // kv_n, axis=2)


def cache_update_decode(cache: KVCache, k_new, v_new) -> KVCache:
    """Append ONE token (k_new/v_new: (B,1,KV,hd))."""
    k_new = _expand_to_cache(cache, k_new)
    v_new = _expand_to_cache(cache, v_new)
    s_cache = cache.k.shape[1]
    pos = jnp.where(cache.ring, cache.length % s_cache,
                    jnp.minimum(cache.length, s_cache - 1))
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, pos, 0, 0))
    return KVCache(k, v, cache.length + 1, cache.ring)


def decode_attention(cfg: ModelConfig, q, cache: KVCache,
                     start: Optional[jax.Array] = None) -> jax.Array:
    """One-token attention against the cache. q: (B,1,H,hd).

    The cache position of the current token must already be written
    (call :func:`cache_update_decode` first). Works for both layouts:
    for the ring cache, positions are validated modulo the window.

    ``start`` — (B,) int32 — marks each row's first valid cache slot: the
    serve engine left-pads mixed-length prompts (and admits new requests
    mid-stream at ``cur - plen``), so slots below ``start[b]`` hold pad or
    stale K/V and must not be attended. Full-cache layout only (the ring
    cache re-uses slots, so a per-row start offset is not meaningful there;
    the engine batches ring archs by equal prompt length instead).
    """
    b, _, h, hd = q.shape
    s_cache = cache.k.shape[1]
    n_rep = h // cache.k.shape[2]
    # OPT(kv_fp8): the cache may be stored in float8_e4m3fn (half the HBM
    # traffic of bf16 — the dominant decode roofline term); dequantize to
    # the compute dtype at read.
    k = _repeat_kv(cache.k, n_rep).astype(q.dtype)
    v = _repeat_kv(cache.v, n_rep).astype(q.dtype)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    # validity: slot i holds absolute position p(i); valid iff p(i) <= cur.
    idx = jnp.arange(s_cache)
    cur = cache.length  # tokens written INCLUDING the current one
    if cache.ring:
        # slot i holds the latest absolute position congruent to i (mod S).
        valid = jnp.broadcast_to(idx < jnp.minimum(cur, s_cache),
                                 (b, s_cache))
    else:
        valid = jnp.broadcast_to(idx < cur, (b, s_cache))
        if start is not None:
            valid = valid & (idx[None, :] >= start[:, None])
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# flash-decode partial-softmax combine (beyond-paper: used when the KV cache
# sequence is sharded across the mesh — the long_500k layout)
# ---------------------------------------------------------------------------

def partial_attention(q, k, v, valid) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Attention over a sequence SHARD; returns (out, max, sum-exp) so shards
    combine exactly: the standard flash-decode two-pass-free reduction."""
    hd = q.shape[-1]
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)                 # (B,H,Q,1)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)
    return out, m, l


def combine_partials(outs, ms, ls):
    """Combine per-shard (out, m, l) triples along a new leading axis."""
    m_glob = jnp.max(ms, axis=0)                                # (B,H,Q,1)
    alpha = jnp.exp(ms - m_glob)                                # (N,B,H,Q,1)
    l_glob = jnp.sum(ls * alpha, axis=0)
    # out: (N,B,Q,H,hd); alpha is (N,B,H,Q,1) -> transpose to (N,B,Q,H,1)
    alpha_o = jnp.transpose(alpha, (0, 1, 3, 2, 4))
    out = jnp.sum(outs.astype(jnp.float32) * alpha_o, axis=0)
    l_o = jnp.transpose(l_glob, (0, 2, 1, 3))                   # (B,Q,H,1)
    return (out / jnp.maximum(l_o, 1e-30)).astype(outs.dtype)
