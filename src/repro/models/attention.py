"""Attention: GQA/MQA, causal + sliding-window masks, KV-cache decode.

Two execution paths:

* the XLA path (below) — used for CPU smoke tests and for every dry-run
  compile (Pallas does not lower to the CPU backend);
* the Pallas path (``repro.kernels.ops.flash_attention``) — the TPU-target
  kernel, numerically validated against ``repro.kernels.ref`` in tests; the
  model selects it with ``use_pallas=True`` on TPU.

Decode supports two cache layouts:

* full cache ``(B, S_max, KV, hd)`` with a write cursor;
* ring cache ``(B, W, KV, hd)`` for sliding-window archs — O(W) memory at
  any context length, which is what qualifies dense archs for ``long_500k``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B,S,KV,hd) -> (B,S,KV*n_rep,hd) for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


def causal_mask(q_len: int, kv_len: int, *, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """(q_len, kv_len) bool mask. ``window`` adds the sliding-window band."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    m = k_pos <= q_pos
    if window is not None:
        m &= k_pos > q_pos - window
    return m


def attention(cfg: ModelConfig, q, k, v, *, q_offset: int = 0,
              mask: Optional[jax.Array] = None,
              start: Optional[jax.Array] = None) -> jax.Array:
    """Full (prefill/train) attention. q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).

    ``start`` — (B,) int32 left-pad lengths — masks each row's pad prefix
    (key positions ``< start[b]``) so mixed-length prompts prefill exactly
    as they would alone. ``mask`` may be (Sq, Skv) shared or (B, Sq, Skv)
    per-row.
    """
    b, sq, h, hd = q.shape
    n_rep = h // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is None:
        mask = causal_mask(sq, k.shape[1], window=cfg.sliding_window,
                           q_offset=q_offset)
    if start is not None:
        pad_ok = jnp.arange(k.shape[1])[None, :] >= start[:, None]  # (B,Skv)
        mask = (mask[None] if mask.ndim == 2 else mask) & pad_ok[:, None, :]
    logits = jnp.where(mask[None, None] if mask.ndim == 2 else mask[:, None],
                       logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class KVCache:
    """KV cache; ``ring`` is static metadata (not a traced leaf) so caches
    can be scanned over the layer axis."""

    def __init__(self, k, v, length, ring: bool = False):
        self.k = k            # (B, S_cache, KV, hd) — S_cache = S_max or W
        self.v = v
        self.length = length  # () int32: tokens written so far (absolute)
        self.ring = bool(ring)

    def tree_flatten(self):
        return (self.k, self.v, self.length), self.ring

    @classmethod
    def tree_unflatten(cls, ring, children):
        return cls(*children, ring=ring)

    @classmethod
    def init(cls, cfg: ModelConfig, batch: int, max_len: int,
             dtype=jnp.bfloat16) -> "KVCache":
        w = cfg.sliding_window
        s = min(max_len, w) if (w is not None and w < max_len) else max_len
        kvh = cfg.num_kv_heads * max(1, cfg.decode_kv_expand)
        shape = (batch, s, kvh, cfg.head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32), ring=bool(w is not None and w < max_len))


def _expand_heads(k_new, kv_stored: int):
    """OPT(decode_cache): the cache may store each KV head ``e`` times (so
    stored heads == TP degree and attention shards losslessly); expand the
    incoming head dim (axis 2 of (B,S,KV,hd)) to match."""
    kv_n = k_new.shape[2]
    if kv_stored == kv_n:
        return k_new
    assert kv_stored % kv_n == 0, (kv_stored, kv_n)
    return jnp.repeat(k_new, kv_stored // kv_n, axis=2)


def _expand_to_cache(cache: KVCache, k_new):
    return _expand_heads(k_new, cache.k.shape[2])


def cache_update_decode(cache: KVCache, k_new, v_new) -> KVCache:
    """Append ONE token (k_new/v_new: (B,1,KV,hd))."""
    k_new = _expand_to_cache(cache, k_new)
    v_new = _expand_to_cache(cache, v_new)
    s_cache = cache.k.shape[1]
    pos = jnp.where(cache.ring, cache.length % s_cache,
                    jnp.minimum(cache.length, s_cache - 1))
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, pos, 0, 0))
    return KVCache(k, v, cache.length + 1, cache.ring)


def decode_attention(cfg: ModelConfig, q, cache: KVCache,
                     start: Optional[jax.Array] = None) -> jax.Array:
    """One-token attention against the cache. q: (B,1,H,hd).

    The cache position of the current token must already be written
    (call :func:`cache_update_decode` first). Works for both layouts:
    for the ring cache, positions are validated modulo the window.

    ``start`` — (B,) int32 — marks each row's first valid cache slot: the
    serve engine left-pads mixed-length prompts (and admits new requests
    mid-stream at ``cur - plen``), so slots below ``start[b]`` hold pad or
    stale K/V and must not be attended. Full-cache layout only (the ring
    cache re-uses slots, so a per-row start offset is not meaningful there;
    the engine batches ring archs by equal prompt length instead).
    """
    b, _, h, hd = q.shape
    s_cache = cache.k.shape[1]
    n_rep = h // cache.k.shape[2]
    # OPT(kv_fp8): the cache may be stored in float8_e4m3fn (half the HBM
    # traffic of bf16 — the dominant decode roofline term); dequantize to
    # the compute dtype at read.
    k = _repeat_kv(cache.k, n_rep).astype(q.dtype)
    v = _repeat_kv(cache.v, n_rep).astype(q.dtype)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    # validity: slot i holds absolute position p(i); valid iff p(i) <= cur.
    idx = jnp.arange(s_cache)
    cur = cache.length  # tokens written INCLUDING the current one
    if cache.ring:
        # slot i holds the latest absolute position congruent to i (mod S).
        valid = jnp.broadcast_to(idx < jnp.minimum(cur, s_cache),
                                 (b, s_cache))
    else:
        valid = jnp.broadcast_to(idx < cur, (b, s_cache))
        if start is not None:
            valid = valid & (idx[None, :] >= start[:, None])
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# paged KV cache: fixed page pool + per-slot page table
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class PagedKVCache:
    """Layer-stacked paged KV cache.

    Instead of one contiguous ``(L, B, S_max, KV, hd)`` buffer, K/V live in
    a fixed pool of fixed-size pages ``(L, num_pages, page_size, KV, hd)``
    with a per-slot page table ``(B, max_pages)`` mapping each slot's
    logical page (virtual position ``p`` -> logical page ``p // page_size``)
    to a pool page, ``-1`` = unmapped. Pool page 0 is the engine's TRASH
    page: writes routed through an unmapped table entry (pad prefix,
    finished slots) land there and are never validly read — attention masks
    by ``[start, length)`` exactly as on the contiguous cache, so the two
    layouts are token-identical by construction.

    The table is shared by every layer (one allocation covers the whole
    stack); ``page_size`` is static metadata so caches scan over the layer
    axis. Allocation lives in :mod:`repro.serve.paging`.
    """

    def __init__(self, k, v, table, length, page_size: int):
        self.k = k                # (L, NP, PS, KV, hd)
        self.v = v
        self.table = table        # (B, MAXP) int32
        self.length = length      # () int32 — absolute write cursor
        self.page_size = int(page_size)

    def tree_flatten(self):
        return (self.k, self.v, self.table, self.length), self.page_size

    @classmethod
    def tree_unflatten(cls, page_size, children):
        return cls(*children, page_size=page_size)


@jax.tree_util.register_pytree_node_class
class PagedKVLayer:
    """One layer's view of a :class:`PagedKVCache` (pool slice + the shared
    table/cursor) — what the per-layer block code sees in place of a
    :class:`KVCache`."""

    def __init__(self, k, v, table, length, page_size: int):
        self.k = k                # (NP, PS, KV, hd)
        self.v = v
        self.table = table        # (B, MAXP) int32
        self.length = length      # () int32
        self.page_size = int(page_size)

    def tree_flatten(self):
        return (self.k, self.v, self.table, self.length), self.page_size

    @classmethod
    def tree_unflatten(cls, page_size, children):
        return cls(*children, page_size=page_size)


def _paged_write_ids(table, pos, page_size):
    """Pool page ids for writing virtual position(s) ``pos`` per slot;
    unmapped entries route to the trash page (0)."""
    ids = jnp.take(table, pos // page_size, axis=1)   # (B,) or (B, n)
    return jnp.where(ids >= 0, ids, 0)


def paged_update_decode(layer: PagedKVLayer, k_new, v_new) -> PagedKVLayer:
    """Append ONE token (k_new/v_new: (B,1,KVn,hd)) at the shared cursor.

    Every slot writes pool page ``table[b, cur // PS]`` at in-page offset
    ``cur % PS`` — distinct pages by the allocator's unique-ownership
    invariant, so the scatter never collides (except in the trash page,
    whose content is never read)."""
    ps = layer.page_size
    k_new = _expand_heads(k_new, layer.k.shape[2])
    v_new = _expand_heads(v_new, layer.k.shape[2])
    pos = layer.length
    ids = _paged_write_ids(layer.table, pos[None], ps)[:, 0]  # (B,)
    off = pos % ps
    k = layer.k.at[ids, off].set(k_new[:, 0].astype(layer.k.dtype))
    v = layer.v.at[ids, off].set(v_new[:, 0].astype(layer.v.dtype))
    return PagedKVLayer(k, v, layer.table, layer.length + 1, ps)


def paged_prefill_update(layer: PagedKVLayer, k_new, v_new) -> PagedKVLayer:
    """Write a fresh prefill (k_new/v_new: (B,S,KVn,hd)) at positions
    ``[0, S)`` — whole pages scattered into the pool; positions whose pages
    are unmapped (each slot's left-pad prefix) go to the trash page."""
    ps = layer.page_size
    k_new = _expand_heads(k_new, layer.k.shape[2])
    v_new = _expand_heads(v_new, layer.k.shape[2])
    b, s = k_new.shape[:2]
    npg = -(-s // ps)
    pad = npg * ps - s
    if pad:
        k_new = jnp.pad(k_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_new = jnp.pad(v_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = k_new.reshape((b, npg, ps) + k_new.shape[2:])
    vp = v_new.reshape((b, npg, ps) + v_new.shape[2:])
    ids = layer.table[:, :npg]
    ids = jnp.where(ids >= 0, ids, 0)                 # (B, npg)
    k = layer.k.at[ids].set(kp.astype(layer.k.dtype))
    v = layer.v.at[ids].set(vp.astype(layer.v.dtype))
    return PagedKVLayer(k, v, layer.table, layer.length + s, ps)


def paged_splice(cache: PagedKVCache, slot, dest, k_rows, v_rows
                 ) -> PagedKVCache:
    """Admission splice: write ``k_rows``/``v_rows`` (``(L, S, KV, hd)``)
    into ``slot``'s pages at virtual positions ``[dest, dest + S)`` — the
    paged analogue of the contiguous engine's dynamic_update_slice splice,
    page-table-indirect and not page-aligned (positions below the admitted
    request's ``start`` fall through unmapped entries to the trash page)."""
    ps = cache.page_size
    ll, np_, _, kv, hd = cache.k.shape
    s = k_rows.shape[1]
    pos = jnp.asarray(dest, jnp.int32) + jnp.arange(s, dtype=jnp.int32)
    row = jnp.take(cache.table, jnp.asarray(slot, jnp.int32), axis=0)
    ids = jnp.take(row, pos // ps)
    ids = jnp.where(ids >= 0, ids, 0)
    flat = ids * ps + pos % ps                        # (S,)
    k = cache.k.reshape(ll, np_ * ps, kv, hd)
    v = cache.v.reshape(ll, np_ * ps, kv, hd)
    k = k.at[:, flat].set(k_rows.astype(k.dtype)).reshape(cache.k.shape)
    v = v.at[:, flat].set(v_rows.astype(v.dtype)).reshape(cache.v.shape)
    return PagedKVCache(k, v, cache.table, cache.length, ps)


def paged_decode_attention(cfg: ModelConfig, q, layer: PagedKVLayer,
                           start: Optional[jax.Array] = None) -> jax.Array:
    """One-token attention against the paged cache: gather each slot's
    pages into sequence order (Pallas tile-gather on TPU, one jnp.take
    elsewhere — :mod:`repro.kernels.paged_kv`), then the standard masked
    decode attention. Validity is identical to the contiguous layout —
    ``[start, length)`` — which is what makes paged-vs-contiguous token
    equality exact rather than approximate."""
    from repro.kernels.paged_kv import paged_gather
    k_view = paged_gather(layer.k, layer.table)
    v_view = paged_gather(layer.v, layer.table)
    view = KVCache(k_view, v_view, layer.length, ring=False)
    return decode_attention(cfg, q, view, start=start)


# ---------------------------------------------------------------------------
# flash-decode partial-softmax combine (beyond-paper: used when the KV cache
# sequence is sharded across the mesh — the long_500k layout)
# ---------------------------------------------------------------------------

def partial_attention(q, k, v, valid) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Attention over a sequence SHARD; returns (out, max, sum-exp) so shards
    combine exactly: the standard flash-decode two-pass-free reduction."""
    hd = q.shape[-1]
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)                 # (B,H,Q,1)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)
    return out, m, l


def combine_partials(outs, ms, ls):
    """Combine per-shard (out, m, l) triples along a new leading axis."""
    m_glob = jnp.max(ms, axis=0)                                # (B,H,Q,1)
    alpha = jnp.exp(ms - m_glob)                                # (N,B,H,Q,1)
    l_glob = jnp.sum(ls * alpha, axis=0)
    # out: (N,B,Q,H,hd); alpha is (N,B,H,Q,1) -> transpose to (N,B,Q,H,1)
    alpha_o = jnp.transpose(alpha, (0, 1, 3, 2, 4))
    out = jnp.sum(outs.astype(jnp.float32) * alpha_o, axis=0)
    l_o = jnp.transpose(l_glob, (0, 2, 1, 3))                   # (B,Q,H,1)
    return (out / jnp.maximum(l_o, 1e-30)).astype(outs.dtype)
