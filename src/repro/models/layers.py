"""Shared neural-net building blocks (pure JAX, functional)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rms_norm(x, scale=None, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    return x.astype(dt)


def layer_norm(x, scale=None, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(cfg: ModelConfig, x, params: Optional[dict]):
    """Dispatch on cfg.norm. ``nonparametric`` (OLMo) takes no params."""
    if cfg.norm == "nonparametric":
        return layer_norm(x, None, None)
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params.get("bias"))
    return rms_norm(x, params["scale"])


# ---------------------------------------------------------------------------
# activations / gated FFN
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def gated_ffn(cfg: ModelConfig, x, p, shard=None, comm=None,
              purpose: str = "tp_mlp"):
    """GeGLU/SwiGLU: act(x @ w_gate) * (x @ w_up) @ w_down.

    Under the manual-TP serve path (``comm`` set) w_gate/w_up arrive
    column-sharded and w_down row-sharded, so ``h @ w_down`` is a partial
    sum: it is all-reduced on the purpose's VCI stream, and the replicated
    ``b_down`` is added AFTER the reduce (adding it to the partial would
    count it tp times).
    """
    a = act_fn(cfg.hidden_act)
    h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    if "b_up" in p:
        h = h + p["b_up"]
    if shard is not None:
        h = shard.ffn_hidden(h)
    y = h @ p["w_down"]
    if comm is not None:
        y = comm.psum(y, purpose)
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# gradient dtype boundary (OPT bf16_grads — EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def bf16_grad_boundary(x):
    """Identity fwd; bwd rounds the cotangent through bf16 AND returns it in
    bf16. Placed after the TP matmuls so the backward partial-sum
    all-reduces carry 2-byte payloads (the f32 norm math upstream otherwise
    makes XLA hoist a convert-to-f32 BEFORE the all-reduce, doubling link
    bytes)."""
    return x


def _bf16_fwd(x):
    return x, None


def _bf16_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grad_boundary.defvjp(_bf16_fwd, _bf16_bwd)


def maybe_bf16_grads(cfg: ModelConfig, x):
    if "bf16_grads" in cfg.opts:
        return bf16_grad_boundary(x)
    return x


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if ang.ndim == 2:  # (S, hd/2) -> broadcast batch
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]    # (B,S,1,hd/2)
    x1, x2 = x[..., ::2], x[..., 1::2]
    dt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(dt)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)
