"""Mamba2 / SSD (state-space duality) layer [arXiv:2405.21060].

Train/prefill uses the blocked SSD algorithm: the sequence is split into
chunks of ``chunk_size``; within a chunk the quadratic (attention-dual) form
runs on the MXU, across chunks a low-rank state recurrence propagates the
``(H, N, P)`` state via an associative scan. Decode is the O(1) recurrent
update.

This module is the pure-jnp reference implementation used by the model's XLA
path; ``repro.kernels.ssd_scan`` is the Pallas TPU kernel for the intra-chunk
part, validated against :func:`ssd_chunked` in tests.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# the SSD scan itself (head-parallel; f32 internally)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, *, chunk: int,
                initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Blocked SSD.

    x:  (b, s, h, p)   values
    dt: (b, s, h)      positive step sizes (already softplus'd + bias)
    A:  (h,)           negative per-head decay rates
    B:  (b, s, g, n)   input projections  (g groups broadcast over heads)
    C:  (b, s, g, n)   output projections
    returns (y: (b,s,h,p), final_state: (b,h,n,p))
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk:
        # pad with dt=0 steps: decay exp(0)=1 keeps the state, dt_j=0 zeroes
        # the padded tokens' contributions — exact for y[:s] and final_state.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g

    f32 = jnp.float32
    xs = x.reshape(b, nc, chunk, h, p).astype(f32)
    dts = dt.reshape(b, nc, chunk, h).astype(f32)
    Bs = B.reshape(b, nc, chunk, g, n).astype(f32)
    Cs = C.reshape(b, nc, chunk, g, n).astype(f32)

    dA = dts * A.astype(f32)                                 # (b,nc,c,h)
    cum = jnp.cumsum(dA, axis=2)                             # (b,nc,c,h)
    cum_end = cum[:, :, -1:, :]                              # (b,nc,1,h)

    # ---- intra-chunk (quadratic/dual form) --------------------------------
    # L[i,j] = exp(cum_i - cum_j) for j <= i, else 0
    Li = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # (b,nc,i,j,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Li = jnp.where(mask[None, None, :, :, None], Li, 0.0)
    CB = jnp.einsum("bnigq,bnjgq->bnijg", Cs, Bs)            # (b,nc,i,j,g)
    CB = jnp.repeat(CB, rep, axis=4)                         # -> heads
    W = CB * Li * dts[:, :, None, :, :]                      # weight on x_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", W, xs)

    # ---- per-chunk local states --------------------------------------------
    decay_end = jnp.exp(cum_end - cum)                       # (b,nc,c,h)
    Br = jnp.repeat(Bs, rep, axis=3)                         # groups -> heads
    Bx = jnp.einsum("bnchq,bnchp,bnch->bnhqp",
                    Br, xs, dts * decay_end)                 # (b,nc,h,n,p)

    # ---- inter-chunk recurrence (associative scan) -------------------------
    a = jnp.exp(cum_end[:, :, 0, :])                         # (b,nc,h)
    a_full = a[..., None, None]                              # (b,nc,h,1,1)

    def op(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2 * s1 + s2

    if initial_state is not None:
        init = initial_state.astype(f32)[:, None]            # (b,1,h,n,p)
        ones = jnp.ones((b, 1, h, 1, 1), f32)
        a_full = jnp.concatenate([ones, a_full], axis=1)
        Bx = jnp.concatenate([init, Bx], axis=1)
    acc_a, acc_s = jax.lax.associative_scan(op, (a_full, Bx), axis=1)
    if initial_state is not None:
        acc_s_incl = acc_s[:, 1:]
    else:
        acc_s_incl = acc_s
    final_state = acc_s_incl[:, -1]                          # (b,h,n,p)
    # state ENTERING chunk k = inclusive state after chunk k-1
    zeros = jnp.zeros((b, 1, h, n, p), f32)
    if initial_state is not None:
        s_prev = jnp.concatenate([init, acc_s_incl[:, :-1]], axis=1)
    else:
        s_prev = jnp.concatenate([zeros, acc_s_incl[:, :-1]], axis=1)

    decay_in = jnp.exp(cum)                                  # (b,nc,c,h)
    Cr = jnp.repeat(Cs, rep, axis=3)                         # (b,nc,c,h,n)
    y_inter = jnp.einsum("bnchq,bnhqp,bnch->bnchp", Cr, s_prev, decay_in)

    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """O(1) recurrent step.

    state: (b,h,n,p); x: (b,h,p); dt: (b,h); A: (h,); B,C: (b,g,n)
    returns (y: (b,h,p), new_state)
    """
    f32 = jnp.float32
    rep = x.shape[1] // B.shape[1]
    Bh = jnp.repeat(B.astype(f32), rep, axis=1)              # (b,h,n)
    Ch = jnp.repeat(C.astype(f32), rep, axis=1)
    dtf = dt.astype(f32)
    decay = jnp.exp(dtf * A.astype(f32))[..., None, None]    # (b,h,1,1)
    inject = jnp.einsum("bhq,bhp,bh->bhqp", Bh, x.astype(f32), dtf)
    new_state = decay * state.astype(f32) + inject
    y = jnp.einsum("bhq,bhqp->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# the full Mamba2 block (projections + conv + scan + gated norm)
# ---------------------------------------------------------------------------

class SSMState(NamedTuple):
    conv: jax.Array   # (b, conv_width-1, d_conv_channels)
    ssd: jax.Array    # (b, h, n, p)

    @classmethod
    def init(cls, cfg: ModelConfig, batch: int, dtype=jnp.float32) -> "SSMState":
        c = cfg.ssm
        d_in = c.d_inner(cfg.d_model)
        ch = d_in + 2 * c.ngroups * c.d_state
        h = c.num_heads(cfg.d_model)
        return cls(
            jnp.zeros((batch, c.conv_width - 1, ch), dtype),
            jnp.zeros((batch, h, c.d_state, c.head_dim), jnp.float32),
        )


def _split_proj(cfg: ModelConfig, zxbcdt):
    c = cfg.ssm
    d_in = c.d_inner(cfg.d_model)
    d_bc = 2 * c.ngroups * c.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + d_bc], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w):
    """Depthwise causal conv. xbc: (b,s,ch); w: (width, ch)."""
    width = w.shape[0]
    pad = jnp.zeros_like(xbc[:, : width - 1])
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i: i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(xbc.dtype)


def mamba2_forward(cfg: ModelConfig, x, p, shard=None,
                   initial: Optional[SSMState] = None
                   ) -> Tuple[jax.Array, SSMState]:
    """Full-sequence Mamba2 block. x: (b,s,d) -> (y: (b,s,d), final state)."""
    c = cfg.ssm
    b, s, _ = x.shape
    d_in = c.d_inner(cfg.d_model)
    h = c.num_heads(cfg.d_model)

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"]))
    xv, B, C = jnp.split(xbc, [d_in, d_in + c.ngroups * c.d_state], axis=-1)
    xv = xv.reshape(b, s, h, c.head_dim)
    B = B.reshape(b, s, c.ngroups, c.d_state)
    C = C.reshape(b, s, c.ngroups, c.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if shard is not None:
        xv = shard.heads(xv)

    init_ssd = initial.ssd if initial is not None else None
    y, final = ssd_chunked(xv, dt, A, B, C, chunk=c.chunk_size,
                           initial_state=init_ssd)
    y = y + xv * p["D"].astype(jnp.float32)[None, None, :, None].astype(xv.dtype)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = y @ p["out_proj"].astype(y.dtype)

    # conv tail state for decode continuation
    pad_needed = c.conv_width - 1
    raw_xbc = _split_proj(cfg, zxbcdt)[1]
    conv_state = raw_xbc[:, -pad_needed:] if s >= pad_needed else jnp.pad(
        raw_xbc, ((0, 0), (pad_needed - s, 0), (0, 0)))
    return out, SSMState(conv_state, final)


def mamba2_decode(cfg: ModelConfig, x, p, state: SSMState,
                  shard=None) -> Tuple[jax.Array, SSMState]:
    """One-token Mamba2 step. x: (b,1,d)."""
    c = cfg.ssm
    b = x.shape[0]
    d_in = c.d_inner(cfg.d_model)
    h = c.num_heads(cfg.d_model)

    zxbcdt = x[:, 0] @ p["in_proj"].astype(x.dtype)          # (b, proj)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    # conv over [state ; new]
    window = jnp.concatenate([state.conv, xbc[:, None]], axis=1)  # (b,w,ch)
    w = p["conv_w"].astype(jnp.float32)
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
                      ).astype(x.dtype)
    new_conv = window[:, 1:].astype(state.conv.dtype)

    xv, B, C = jnp.split(xbc, [d_in, d_in + c.ngroups * c.d_state], axis=-1)
    xv = xv.reshape(b, h, c.head_dim)
    B = B.reshape(b, c.ngroups, c.d_state)
    C = C.reshape(b, c.ngroups, c.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, new_ssd = ssd_decode_step(state.ssd, xv, dt, A, B, C)
    y = y + xv * p["D"].astype(jnp.float32)[None, :, None].astype(xv.dtype)
    y = y.reshape(b, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = (y @ p["out_proj"].astype(y.dtype))[:, None]
    return out, SSMState(new_conv, new_ssd)
