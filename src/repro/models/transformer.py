"""The unified model over all assigned architecture families.

One ``Model`` class covers: dense GQA/MQA transformers (gemma/yi/command-r/
olmo), MoE (mixtral/arctic), SSM (mamba2), hybrid SSM+shared-attention
(zamba2), VLM (phi-3-vision: stubbed patch embeddings spliced before text)
and audio (musicgen: 4 EnCodec codebook streams, summed embeddings, one LM
head per codebook).

Layers are stacked along a leading L axis and executed with ``lax.scan``
(compile-time control for 512-device dry-runs); hybrid archs scan groups of
``hybrid_attn_every`` SSM blocks followed by ONE shared-weight attention
block (zamba2's parameter-sharing trick — the weights are shared, but each
application site keeps its own KV cache).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    KVCache,
    PagedKVCache,
    PagedKVLayer,
    attention,
    cache_update_decode,
    decode_attention,
    paged_decode_attention,
    paged_prefill_update,
    paged_update_decode,
)
from repro.models.layers import (
    apply_norm,
    apply_rope,
    dense_init,
    embed_init,
    gated_ffn,
    maybe_bf16_grads,
)
from repro.models.moe import moe_ffn
from repro.models.ssm import SSMState, mamba2_decode, mamba2_forward

IMG_EMBED_DIM = 1024  # stubbed CLIP patch-embedding width (phi-3-vision)


def _remat_policy(cfg: ModelConfig):
    """remat="block" recomputes everything (incl. the forward TP
    all-reduces); remat="dots" is selective activation recomputation —
    matmul outputs (already all-reduced) are saved, so the backward never
    re-runs forward collectives. EXPERIMENTS.md §Perf."""
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------

def _norm_params(cfg: ModelConfig, dims: Tuple[int, ...], d: Optional[int] = None):
    if cfg.norm == "nonparametric":
        return None
    d = cfg.d_model if d is None else d
    p = {"scale": jnp.ones(dims + (d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(dims + (d,), jnp.float32)
    return p


def _attn_params(cfg: ModelConfig, key, dims: Tuple[int, ...], dtype):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], dims + (d, qd), dtype=dtype),
        "wk": dense_init(ks[1], dims + (d, kvd), dtype=dtype),
        "wv": dense_init(ks[2], dims + (d, kvd), dtype=dtype),
        "wo": dense_init(ks[3], dims + (qd, d), dtype=dtype),
    }
    if cfg.use_bias:
        p |= {
            "bq": jnp.zeros(dims + (qd,), dtype),
            "bk": jnp.zeros(dims + (kvd,), dtype),
            "bv": jnp.zeros(dims + (kvd,), dtype),
            "bo": jnp.zeros(dims + (d,), dtype),
        }
    return p


def _ffn_params(cfg: ModelConfig, key, dims: Tuple[int, ...], dtype, dff=None):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    dff = cfg.d_ff if dff is None else dff
    p = {
        "w_gate": dense_init(ks[0], dims + (d, dff), dtype=dtype),
        "w_up": dense_init(ks[1], dims + (d, dff), dtype=dtype),
        "w_down": dense_init(ks[2], dims + (dff, d), dtype=dtype),
    }
    if cfg.use_bias:
        p |= {"b_up": jnp.zeros(dims + (dff,), dtype),
              "b_down": jnp.zeros(dims + (d,), dtype)}
    return p


def _ssm_params(cfg: ModelConfig, key, dims: Tuple[int, ...], dtype):
    c = cfg.ssm
    d = cfg.d_model
    d_in = c.d_inner(d)
    nh = c.num_heads(d)
    d_bc = 2 * c.ngroups * c.d_state
    proj_out = 2 * d_in + d_bc + nh
    ks = jax.random.split(key, 3)
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[2], dims + (nh,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a_init = jnp.broadcast_to(
        jnp.log(jnp.linspace(1.0, 16.0, nh)), dims + (nh,))
    return {
        "in_proj": dense_init(ks[0], dims + (d, proj_out), dtype=dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], dims + (c.conv_width, d_in + d_bc),
                                          jnp.float32).astype(dtype),
        "A_log": a_init.astype(jnp.float32),
        "D": jnp.ones(dims + (nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gate_norm": jnp.ones(dims + (d_in,), jnp.float32),
        "out_proj": dense_init(ks[0], dims + (d_in, d), dtype=dtype),
    }


def _moe_params(cfg: ModelConfig, key, dims: Tuple[int, ...], dtype):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.d_ff, m.num_experts
    p = {
        "router": dense_init(ks[0], dims + (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], dims + (e, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], dims + (e, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], dims + (e, ff, d), dtype=dtype),
    }
    if m.dense_residual:
        p["residual"] = _ffn_params(cfg, ks[4], dims, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    L = cfg.num_layers
    params: Dict[str, Any] = {}

    if cfg.modality == "audio":
        params["embed"] = {"tok": embed_init(
            keys[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model), dtype)}
    else:
        params["embed"] = {"tok": embed_init(
            keys[0], (cfg.vocab_size, cfg.d_model), dtype)}
    if cfg.modality == "vlm":
        params["img_proj"] = {"w": dense_init(
            keys[1], (IMG_EMBED_DIM, cfg.d_model), dtype=dtype)}

    dims = (L,)
    if cfg.family in ("ssm", "hybrid"):
        layer = {"ssm": _ssm_params(cfg, keys[2], dims, dtype),
                 "norm1": _norm_params(cfg, dims)}
        if cfg.family == "hybrid":
            params["shared_attn"] = {
                "attn": _attn_params(cfg, keys[3], (), dtype),
                "ffn": _ffn_params(cfg, keys[4], (), dtype),
                "norm1": _norm_params(cfg, ()),
                "norm2": _norm_params(cfg, ()),
            }
    else:
        layer = {"attn": _attn_params(cfg, keys[2], dims, dtype),
                 "norm1": _norm_params(cfg, dims)}
        if cfg.moe is not None:
            layer["moe"] = _moe_params(cfg, keys[3], dims, dtype)
        else:
            layer["ffn"] = _ffn_params(cfg, keys[3], dims, dtype)
        if not cfg.parallel_block:
            layer["norm2"] = _norm_params(cfg, dims)
    params["layers"] = {k: v for k, v in layer.items() if v is not None}

    fn = _norm_params(cfg, ())
    if fn is not None:
        params["final_norm"] = fn
    if not cfg.tie_embeddings:
        if cfg.modality == "audio":
            params["lm_head"] = {"w": dense_init(
                keys[5], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
                dtype=dtype)}
        else:
            params["lm_head"] = {"w": dense_init(
                keys[5], (cfg.d_model, cfg.vocab_size), dtype=dtype)}
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Per-arch decode state, layer-stacked along the leading axis."""

    kv: Optional[KVCache]       # (L|n_sites, B, S, KV, hd) stacked
    ssm: Optional[SSMState]     # (L, ...) stacked
    length: jax.Array           # () int32 — absolute tokens decoded


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> DecodeCache:
    if "kv_fp8" in cfg.opts and jnp.dtype(dtype) == jnp.bfloat16:
        # OPT(kv_fp8): fp8 KV storage — halves the decode memory-roofline
        # term (EXPERIMENTS §Perf); dequantized at attention read.
        dtype = jnp.float8_e4m3fn
    def stack(tree, n):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)

    kv = ssm = None
    if cfg.family in ("ssm", "hybrid"):
        ssm = stack(SSMState.init(cfg, batch, dtype=jnp.float32), cfg.num_layers)
        ssm = SSMState(ssm.conv.astype(dtype), ssm.ssd)
        if cfg.family == "hybrid":
            n_sites = cfg.num_layers // cfg.hybrid_attn_every
            kv0 = KVCache.init(cfg, batch, max_len, dtype)
            kv = KVCache(
                jnp.broadcast_to(kv0.k[None], (n_sites,) + kv0.k.shape),
                jnp.broadcast_to(kv0.v[None], (n_sites,) + kv0.v.shape),
                kv0.length, kv0.ring)
    else:
        kv0 = KVCache.init(cfg, batch, max_len, dtype)
        kv = KVCache(
            jnp.broadcast_to(kv0.k[None], (cfg.num_layers,) + kv0.k.shape),
            jnp.broadcast_to(kv0.v[None], (cfg.num_layers,) + kv0.v.shape),
            kv0.length, kv0.ring)
    return DecodeCache(kv, ssm, jnp.zeros((), jnp.int32))


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int, num_pages: int,
                     dtype=jnp.bfloat16) -> DecodeCache:
    """Paged decode cache: a fixed pool of ``num_pages`` pages of
    ``page_size`` tokens (page 0 reserved as trash) + an all-unmapped
    per-slot page table covering virtual positions ``[0, max_len)``.

    Attention-cache architectures only: ring (sliding-window) caches reuse
    slots modulo the window and SSM state has no per-position pages — the
    serve engine keeps the grouped contiguous fallback for those.
    """
    if cfg.family not in ("dense", "moe") or cfg.modality != "text":
        raise NotImplementedError(
            f"paged KV cache needs a text attention arch, got "
            f"family={cfg.family!r} modality={cfg.modality!r}")
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        raise NotImplementedError(
            "paged KV cache does not support ring (sliding-window) caches; "
            "use the contiguous cache")
    if page_size < 1 or num_pages < 2:
        raise ValueError(f"need page_size >= 1 and num_pages >= 2 "
                         f"(page 0 is the trash page), got "
                         f"{page_size}/{num_pages}")
    if "kv_fp8" in cfg.opts and jnp.dtype(dtype) == jnp.bfloat16:
        dtype = jnp.float8_e4m3fn  # OPT(kv_fp8): see init_cache
    kvh = cfg.num_kv_heads * max(1, cfg.decode_kv_expand)
    max_pages = -(-max_len // page_size)
    shape = (cfg.num_layers, num_pages, page_size, kvh, cfg.head_dim)
    kv = PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                      jnp.full((batch, max_pages), -1, jnp.int32),
                      jnp.zeros((), jnp.int32), page_size)
    return DecodeCache(kv, None, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _layer_kv(kv, l: int):
    """Layer ``l``'s view of a stacked (contiguous or paged) KV cache."""
    if isinstance(kv, PagedKVCache):
        return PagedKVLayer(kv.k[l], kv.v[l], kv.table, kv.length,
                            kv.page_size)
    return KVCache(kv.k[l], kv.v[l], kv.length, kv.ring)


def _restack_kv(kv, ks, vs, advanced: int):
    """Stack per-layer outputs back into the cache's layout; ``advanced`` is
    how many tokens the cursor moved (S for prefill, 1 for decode)."""
    if isinstance(kv, PagedKVCache):
        return PagedKVCache(jnp.stack(ks), jnp.stack(vs), kv.table,
                            kv.length + advanced, kv.page_size)
    return KVCache(jnp.stack(ks), jnp.stack(vs), kv.length + advanced,
                   kv.ring)

def _attn_apply(cfg: ModelConfig, x, p, positions, shard,
                kv: Optional[KVCache] = None, decode: bool = False,
                comm=None, start=None):
    """``comm`` (repro.serve.comm.ServeComm) selects manual TP: weights
    arrive Megatron-sharded, head dims below are LOCAL counts, and the
    row-parallel ``wo`` partial sum is all-reduced on the ``tp_attn`` VCI
    stream. ``start`` is the per-row left-pad offset (serve engine)."""
    b, s, d = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.use_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # -1 head counts: under manual TP each rank holds num_heads/tp heads.
    q = q.reshape(b, s, -1, cfg.head_dim)
    k = k.reshape(b, s, -1, cfg.head_dim)
    v = v.reshape(b, s, -1, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if shard is not None:
        q = shard.heads(q)

    new_kv = None
    if decode:
        if isinstance(kv, PagedKVLayer):
            new_kv = paged_update_decode(kv, k, v)
            o = paged_decode_attention(cfg, q, new_kv, start=start)
        else:
            new_kv = cache_update_decode(kv, k, v)
            if shard is not None:
                new_kv = KVCache(shard.kv_cache(new_kv.k),
                                 shard.kv_cache(new_kv.v),
                                 new_kv.length, new_kv.ring)
            o = decode_attention(cfg, q, new_kv, start=start)
    else:
        o = attention(cfg, q, k, v, start=start)
        if isinstance(kv, PagedKVLayer):  # prefill: write the page pool
            new_kv = paged_prefill_update(kv, k, v)
        elif kv is not None:              # prefill: write the cache
            new_kv = _prefill_cache(kv, k, v)
    o = o.reshape(b, s, -1)
    o = o @ p["wo"].astype(o.dtype)
    if comm is not None:
        o = comm.psum(o, "tp_attn")
    if cfg.use_bias:
        o = o + p["bo"]
    return o, new_kv


def _prefill_cache(kv: KVCache, k, v) -> KVCache:
    from repro.models.attention import _expand_to_cache
    k = _expand_to_cache(kv, k)
    v = _expand_to_cache(kv, v)
    s = k.shape[1]
    s_cache = kv.k.shape[1]
    if kv.ring and s > s_cache:
        # keep the last W tokens, placed to satisfy the ring invariant
        # (slot i holds absolute position ≡ i mod W)
        k, v = k[:, -s_cache:], v[:, -s_cache:]
        shift = s % s_cache
        if shift:
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
    n = min(s, s_cache)
    kc = jax.lax.dynamic_update_slice(kv.k, k[:, :n].astype(kv.k.dtype), (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(kv.v, v[:, :n].astype(kv.v.dtype), (0, 0, 0, 0))
    return KVCache(kc, vc, kv.length + s, kv.ring)


def _dense_block(cfg: ModelConfig, x, p, positions, shard,
                 kv=None, decode=False, comm=None, start=None):
    """Standard (or parallel) transformer block. Returns (x, new_kv, aux)."""
    aux = {}
    if shard is not None:
        p = shard.materialize(p)  # OPT(fsdp): ZeRO weight gather
    inference = decode or kv is not None
    h = apply_norm(cfg, x, p.get("norm1"))
    h = maybe_bf16_grads(cfg, h)  # OPT(bf16_grads): bwd AR in 2-byte payloads
    attn_out, new_kv = _attn_apply(cfg, h, p["attn"], positions, shard,
                                   kv=kv, decode=decode, comm=comm,
                                   start=start)
    if cfg.parallel_block:
        if cfg.moe is not None:
            ffn_out, aux = moe_ffn(cfg, h, p["moe"], shard,
                                   inference=inference, comm=comm)
        else:
            ffn_out = gated_ffn(cfg, h, p["ffn"], shard, comm=comm)
        x = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = apply_norm(cfg, x, p.get("norm2"))
        h2 = maybe_bf16_grads(cfg, h2)
        if cfg.moe is not None:
            ffn_out, aux = moe_ffn(cfg, h2, p["moe"], shard,
                                   inference=inference, comm=comm)
        else:
            ffn_out = gated_ffn(cfg, h2, p["ffn"], shard, comm=comm)
        x = x + ffn_out
    if shard is not None:
        x = shard.hidden(x)
    return x, new_kv, aux


def _ssm_block(cfg: ModelConfig, x, p, shard, state=None, decode=False):
    if shard is not None:
        p = shard.materialize(p)  # OPT(fsdp): ZeRO weight gather
    h = apply_norm(cfg, x, p.get("norm1"))
    if decode:
        out, new_state = mamba2_decode(cfg, h, p["ssm"], state, shard)
    else:
        out, new_state = mamba2_forward(cfg, h, p["ssm"], shard, initial=state)
    x = x + out
    if shard is not None:
        x = shard.hidden(x)
    return x, new_state


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig, shard=None, comm=None):
        """``shard`` — GSPMD sharding-constraint helper (auto axes).
        ``comm`` — :class:`repro.serve.comm.ServeComm` for the manual-TP
        serve path: weights arrive Megatron-sharded via shard_map in_specs
        and every cross-rank exchange is an explicit collective on a
        per-purpose CommContext/VCI stream. Mutually exclusive."""
        assert shard is None or comm is None, "shard and comm are exclusive"
        self.cfg = cfg
        self.shard = shard
        self.comm = comm

    # -- embeddings ------------------------------------------------------
    def _tok_embed(self, emb, tok):
        """Token lookup; vocab-parallel (masked lookup + psum on the
        ``sample`` stream) when the table arrives row-sharded over TP."""
        if self.comm is not None and emb.shape[0] != self.cfg.vocab_size:
            v_loc = emb.shape[0]
            loc = tok - self.comm.rank() * v_loc
            ok = (loc >= 0) & (loc < v_loc)
            x = jnp.where(ok[..., None], emb[jnp.clip(loc, 0, v_loc - 1)], 0)
            return self.comm.psum(x, "sample")
        return emb[tok]

    def embed(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (x: (B,S,d), positions: (B,S) or (S,))."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        tok = batch["tokens"]
        if cfg.modality == "audio":
            # tok: (B, K, S) — sum the K codebook embeddings
            emb = params["embed"]["tok"].astype(dtype)       # (K,V,d)
            x = jnp.sum(jax.vmap(lambda e, t: e[t], in_axes=(0, 1),
                                 out_axes=1)(emb, tok), axis=1)
            positions = jnp.arange(tok.shape[-1])
        elif cfg.modality == "vlm":
            emb = params["embed"]["tok"].astype(dtype)
            xt = emb[tok]                                     # (B,S_txt,d)
            img = batch["image_embeds"].astype(dtype)         # (B,P,1024)
            xi = img @ params["img_proj"]["w"].astype(dtype)
            x = jnp.concatenate([xi, xt], axis=1)
            positions = jnp.arange(x.shape[1])
        else:
            emb = params["embed"]["tok"].astype(dtype)
            x = self._tok_embed(emb, tok)
            positions = jnp.arange(tok.shape[-1])
        if self.shard is not None:
            x = self.shard.hidden(x)
        return x, positions

    def unembed(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = apply_norm(cfg, x, params.get("final_norm"))
        if cfg.modality == "audio":
            w = params["lm_head"]["w"].astype(x.dtype)       # (K,d,V)
            logits = jnp.einsum("bsd,kdv->bksv", x, w)
        elif cfg.tie_embeddings:
            logits = x @ params["embed"]["tok"].astype(x.dtype).T
        else:
            logits = x @ params["lm_head"]["w"].astype(x.dtype)
        if self.comm is not None and logits.shape[-1] != cfg.vocab_size:
            # vocab-parallel logits: gather shards on the sampling stream
            logits = self.comm.all_gather(logits, "sample",
                                          gather_axis=logits.ndim - 1)
        if self.shard is not None and cfg.modality != "audio":
            logits = self.shard.logits(logits)
        return logits

    # -- full-sequence forward (train / prefill) --------------------------
    def forward(self, params, batch, *, cache: Optional[DecodeCache] = None,
                start: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array], Optional[DecodeCache]]:
        """Returns (logits, aux, new_cache). ``cache`` non-None => prefill.

        ``start`` — (B,) int32 left-pad lengths for mixed-length prefill:
        row ``b``'s real tokens occupy positions ``[start[b], S)``; pad
        positions are masked out of attention and RoPE positions are shifted
        so each row computes exactly what it would alone (attention archs
        only — SSM state offers no per-row mask).
        """
        cfg = self.cfg
        x, positions = self.embed(params, batch)
        if start is not None:
            if cfg.family in ("ssm", "hybrid"):
                raise NotImplementedError(
                    "left-padded prefill needs attention masking; SSM "
                    "recurrent state has no per-row pad mask")
            # per-row RoPE positions: the first real token sits at 0
            positions = jnp.maximum(positions[None, :] - start[:, None], 0)
        remat = cfg.remat != "none"

        if cfg.family in ("ssm", "hybrid"):
            x, new_cache = self._ssm_stack(params, x, positions, cache, remat)
            aux: Dict[str, jax.Array] = {}
        else:
            x, aux, new_cache = self._attn_stack(params, x, positions, cache,
                                                 remat, start=start)

        logits = self.unembed(params, x)
        return logits, aux, new_cache

    def _attn_stack(self, params, x, positions, cache, remat, start=None):
        cfg = self.cfg
        if self.comm is not None:
            # VCI streams chain ordering tokens across collectives; a token
            # updated inside a lax.scan body would leak its tracer, so the
            # comm-mode (inference) stack unrolls the layer loop.
            return self._attn_stack_unrolled(params, x, positions, cache,
                                             start)
        if cache is not None and isinstance(cache.kv, PagedKVCache):
            return self._attn_stack_paged(params, x, positions, cache, remat,
                                          start=start)

        def body(carry, scanned):
            x = carry
            if cache is not None:
                lp, kv = scanned
            else:
                lp, kv = scanned, None
            x, new_kv, aux = _dense_block(cfg, x, lp, positions, self.shard,
                                          kv=kv, decode=False, comm=self.comm,
                                          start=start)
            aux_vec = jnp.stack([aux.get("load_balance", jnp.zeros(())),
                                 aux.get("router_z", jnp.zeros(()))])
            return x, (new_kv, aux_vec)

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        if cache is not None:
            kv_stack = KVCache(cache.kv.k, cache.kv.v,
                               jnp.broadcast_to(cache.kv.length, (cfg.num_layers,)),
                               cache.kv.ring)
            x, (kv_out, aux_v) = jax.lax.scan(body, x, (params["layers"], kv_stack))
            new_cache = DecodeCache(
                KVCache(kv_out.k, kv_out.v,
                        cache.kv.length + x.shape[1], cache.kv.ring),
                None, cache.length + x.shape[1])
        else:
            x, (_, aux_v) = jax.lax.scan(body, x, params["layers"])
            new_cache = None
        aux = {"load_balance": aux_v[:, 0].sum(), "router_z": aux_v[:, 1].sum()}
        return x, aux, new_cache

    def _attn_stack_paged(self, params, x, positions, cache, remat,
                          start=None):
        """Prefill into the paged pool: the pool slices scan over the layer
        axis; the page table and write cursor are shared by every layer."""
        cfg = self.cfg
        pk = cache.kv

        def body(carry, scanned):
            x = carry
            lp, kl, vl = scanned
            layer = PagedKVLayer(kl, vl, pk.table, pk.length, pk.page_size)
            x, new_kv, aux = _dense_block(cfg, x, lp, positions, self.shard,
                                          kv=layer, decode=False,
                                          comm=self.comm, start=start)
            aux_vec = jnp.stack([aux.get("load_balance", jnp.zeros(())),
                                 aux.get("router_z", jnp.zeros(()))])
            return x, (new_kv.k, new_kv.v, aux_vec)

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, (k_out, v_out, aux_v) = jax.lax.scan(
            body, x, (params["layers"], pk.k, pk.v))
        s_new = x.shape[1]
        new_cache = DecodeCache(
            PagedKVCache(k_out, v_out, pk.table, pk.length + s_new,
                         pk.page_size),
            None, cache.length + s_new)
        aux = {"load_balance": aux_v[:, 0].sum(), "router_z": aux_v[:, 1].sum()}
        return x, aux, new_cache

    def _attn_stack_unrolled(self, params, x, positions, cache, start=None):
        """Python-loop layer stack for the comm (VCI-stream) serve path."""
        cfg = self.cfg
        take = jax.tree_util.tree_map
        ks, vs = [], []
        lb = rz = jnp.zeros(())
        for l in range(cfg.num_layers):
            lp = take(lambda a: a[l], params["layers"])
            kv = None
            if cache is not None:
                kv = _layer_kv(cache.kv, l)
            x, new_kv, aux = _dense_block(cfg, x, lp, positions, None,
                                          kv=kv, decode=False,
                                          comm=self.comm, start=start)
            if new_kv is not None:
                ks.append(new_kv.k)
                vs.append(new_kv.v)
            lb = lb + aux.get("load_balance", jnp.zeros(()))
            rz = rz + aux.get("router_z", jnp.zeros(()))
        new_cache = None
        if cache is not None:
            new_cache = DecodeCache(
                _restack_kv(cache.kv, ks, vs, x.shape[1]),
                None, cache.length + x.shape[1])
        return x, {"load_balance": lb, "router_z": rz}, new_cache

    def _ssm_stack(self, params, x, positions, cache, remat):
        cfg = self.cfg
        k = cfg.hybrid_attn_every
        L = cfg.num_layers

        def ssm_body(carry, scanned):
            x = carry
            if cache is not None:
                lp, st = scanned
            else:
                lp, st = scanned, None
            x, new_st = _ssm_block(cfg, x, lp, self.shard, state=st, decode=False)
            return x, new_st

        if remat:
            ssm_body = jax.checkpoint(ssm_body, policy=_remat_policy(cfg))

        if cfg.family == "ssm":
            if cache is not None:
                x, st_out = jax.lax.scan(ssm_body, x, (params["layers"], cache.ssm))
                return x, DecodeCache(None, st_out, cache.length + x.shape[1])
            x, _ = jax.lax.scan(ssm_body, x, params["layers"])
            return x, None

        # ---- hybrid: groups of k ssm blocks + shared attention --------------
        n_groups, rem = divmod(L, k)
        lp_all = params["layers"]
        take = jax.tree_util.tree_map
        lp_main = take(lambda a: a[: n_groups * k].reshape((n_groups, k) + a.shape[1:]),
                       lp_all)
        lp_rem = take(lambda a: a[n_groups * k:], lp_all)
        sa = params["shared_attn"]

        def attn_site(x, kv, decode=False):
            h = apply_norm(cfg, x, sa.get("norm1"))
            o, new_kv = _attn_apply(cfg, h, sa["attn"], positions, self.shard,
                                    kv=kv, decode=decode)
            x = x + o
            h2 = apply_norm(cfg, x, sa.get("norm2"))
            x = x + gated_ffn(cfg, h2, sa["ffn"], self.shard)
            return x, new_kv

        def group_body(carry, scanned):
            x = carry
            if cache is not None:
                (lps, sts, kvs) = scanned
                x, st_out = jax.lax.scan(ssm_body, x, (lps, sts))
                x, kv_out = attn_site(x, kvs)
                return x, (st_out, kv_out)
            lps = scanned
            x, _ = jax.lax.scan(ssm_body, x, lps)
            x, _ = attn_site(x, None)
            return x, None

        if remat:
            group_body = jax.checkpoint(group_body, policy=_remat_policy(cfg))

        if cache is not None:
            st_all = cache.ssm
            st_main = take(lambda a: a[: n_groups * k].reshape(
                (n_groups, k) + a.shape[1:]), st_all)
            st_rem = take(lambda a: a[n_groups * k:], st_all)
            kv_in = KVCache(cache.kv.k, cache.kv.v,
                            jnp.broadcast_to(cache.kv.length, (n_groups,)),
                            cache.kv.ring)
            x, (st_out, kv_out) = jax.lax.scan(
                group_body, x, (lp_main, st_main, kv_in))
            if rem:
                x, st_rem_out = jax.lax.scan(ssm_body, x, (lp_rem, st_rem))
                st_out = take(
                    lambda a, b: jnp.concatenate(
                        [a.reshape((n_groups * k,) + a.shape[2:]), b]),
                    st_out, st_rem_out)
            else:
                st_out = take(lambda a: a.reshape((n_groups * k,) + a.shape[2:]),
                              st_out)
            s_new = x.shape[1]
            new_cache = DecodeCache(
                KVCache(kv_out.k, kv_out.v, cache.kv.length + s_new, cache.kv.ring),
                st_out, cache.length + s_new)
            return x, new_cache

        x, _ = jax.lax.scan(group_body, x, lp_main)
        if rem:
            x, _ = jax.lax.scan(ssm_body, x, lp_rem)
        return x, None

    # -- one-token decode --------------------------------------------------
    def decode_step(self, params, tokens, cache: DecodeCache,
                    start: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, DecodeCache]:
        """tokens: (B,1) (or (B,K,1) audio). Returns (logits, new_cache).

        ``start`` — (B,) int32 per-row first-valid cache slot (the serve
        engine's left-pad/late-admission offset): cache reads mask slots
        below it and RoPE positions count from it.
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        if cfg.modality == "audio":
            emb = params["embed"]["tok"].astype(dtype)
            x = jnp.sum(jax.vmap(lambda e, t: e[t], in_axes=(0, 1),
                                 out_axes=1)(emb, tokens), axis=1)
        else:
            x = self._tok_embed(params["embed"]["tok"].astype(dtype), tokens)
        if start is not None:
            if cfg.family in ("ssm", "hybrid"):
                raise NotImplementedError(
                    "per-row start offsets need attention masking")
            positions = (cache.length - start)[:, None]
        else:
            positions = cache.length[None, None] + jnp.zeros(
                (x.shape[0], 1), jnp.int32)
        if self.shard is not None:
            x = self.shard.hidden(x)

        if cfg.family in ("ssm", "hybrid"):
            x, new_cache = self._decode_ssm(params, x, positions, cache)
        else:
            x, new_cache = self._decode_attn(params, x, positions, cache,
                                             start=start)
        logits = self.unembed(params, x)
        return logits, new_cache

    def _decode_attn(self, params, x, positions, cache, start=None):
        cfg = self.cfg
        if self.comm is not None:  # unrolled: see _attn_stack_unrolled
            take = jax.tree_util.tree_map
            ks, vs = [], []
            for l in range(cfg.num_layers):
                lp = take(lambda a: a[l], params["layers"])
                kv = _layer_kv(cache.kv, l)
                x, new_kv, _ = _dense_block(cfg, x, lp, positions, None,
                                            kv=kv, decode=True,
                                            comm=self.comm, start=start)
                ks.append(new_kv.k)
                vs.append(new_kv.v)
            new_cache = DecodeCache(_restack_kv(cache.kv, ks, vs, 1),
                                    None, cache.length + 1)
            return x, new_cache

        if isinstance(cache.kv, PagedKVCache):
            pk = cache.kv

            def paged_body(carry, scanned):
                x = carry
                lp, kl, vl = scanned
                layer = PagedKVLayer(kl, vl, pk.table, pk.length,
                                     pk.page_size)
                x, new_kv, _ = _dense_block(cfg, x, lp, positions,
                                            self.shard, kv=layer,
                                            decode=True, comm=self.comm,
                                            start=start)
                return x, (new_kv.k, new_kv.v)

            x, (k_out, v_out) = jax.lax.scan(
                paged_body, x, (params["layers"], pk.k, pk.v))
            new_cache = DecodeCache(
                PagedKVCache(k_out, v_out, pk.table, pk.length + 1,
                             pk.page_size),
                None, cache.length + 1)
            return x, new_cache

        def body(carry, scanned):
            x = carry
            lp, kv = scanned
            x, new_kv, _ = _dense_block(cfg, x, lp, positions, self.shard,
                                        kv=kv, decode=True, comm=self.comm,
                                        start=start)
            return x, new_kv

        kv_stack = KVCache(cache.kv.k, cache.kv.v,
                           jnp.broadcast_to(cache.kv.length, (cfg.num_layers,)),
                           cache.kv.ring)
        x, kv_out = jax.lax.scan(body, x, (params["layers"], kv_stack))
        new_cache = DecodeCache(
            KVCache(kv_out.k, kv_out.v, cache.kv.length + 1, cache.kv.ring),
            None, cache.length + 1)
        return x, new_cache

    def _decode_ssm(self, params, x, positions, cache):
        cfg = self.cfg
        k = cfg.hybrid_attn_every
        L = cfg.num_layers
        take = jax.tree_util.tree_map

        def ssm_body(carry, scanned):
            x = carry
            lp, st = scanned
            x, new_st = _ssm_block(cfg, x, lp, self.shard, state=st, decode=True)
            return x, new_st

        if cfg.family == "ssm":
            x, st_out = jax.lax.scan(ssm_body, x, (params["layers"], cache.ssm))
            return x, DecodeCache(None, st_out, cache.length + 1)

        n_groups, rem = divmod(L, k)
        lp_all = params["layers"]
        lp_main = take(lambda a: a[: n_groups * k].reshape(
            (n_groups, k) + a.shape[1:]), lp_all)
        lp_rem = take(lambda a: a[n_groups * k:], lp_all)
        st_main = take(lambda a: a[: n_groups * k].reshape(
            (n_groups, k) + a.shape[1:]), cache.ssm)
        st_rem = take(lambda a: a[n_groups * k:], cache.ssm)
        sa = params["shared_attn"]

        def group_body(carry, scanned):
            x = carry
            lps, sts, kvs = scanned
            x, st_out = jax.lax.scan(ssm_body, x, (lps, sts))
            h = apply_norm(cfg, x, sa.get("norm1"))
            o, new_kv = _attn_apply(cfg, h, sa["attn"], positions, self.shard,
                                    kv=kvs, decode=True)
            x = x + o
            h2 = apply_norm(cfg, x, sa.get("norm2"))
            x = x + gated_ffn(cfg, h2, sa["ffn"], self.shard)
            return x, (st_out, new_kv)

        kv_in = KVCache(cache.kv.k, cache.kv.v,
                        jnp.broadcast_to(cache.kv.length, (n_groups,)),
                        cache.kv.ring)
        x, (st_out, kv_out) = jax.lax.scan(group_body, x, (lp_main, st_main, kv_in))
        st_out = take(lambda a: a.reshape((n_groups * k,) + a.shape[2:]), st_out)
        if rem:
            x, st_rem_out = jax.lax.scan(ssm_body, x, (lp_rem, st_rem))
            st_out = take(lambda a, b: jnp.concatenate([a, b]), st_out, st_rem_out)
        new_cache = DecodeCache(
            KVCache(kv_out.k, kv_out.v, cache.kv.length + 1, cache.kv.ring),
            st_out, cache.length + 1)
        return x, new_cache
