"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Dispatch is GSPMD-friendly: per batch-row (group) we sort token→expert
assignments, compute each assignment's rank within its expert (capacity
dropping), scatter into an ``(E, C, d)`` buffer, reshard so experts land on
the ``data`` axis (expert parallelism — the resharding lowers to all_to_all,
the MoE analogue of the paper's parallel communication streams), run the
expert FFNs, and combine back with the router gates.

Aux losses: Switch-style load-balance loss and router z-loss.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, gated_ffn


def capacity(tokens_per_group: int, num_experts: int, cf: float, top_k: int) -> int:
    c = int(math.ceil(tokens_per_group * top_k * cf / num_experts))
    return max(4, c)


def moe_ffn(cfg: ModelConfig, x, p, shard=None, *, inference: bool = False,
            comm=None) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux). One group per batch row.

    ``comm`` (a :class:`repro.serve.comm.ServeComm`) selects the manual-TP
    serve path: activations are replicated over the TP axis, expert tables
    arrive expert-parallel (E over the axis) or ff-TP sharded, and the
    combine collective rides the dedicated ``moe`` VCI stream instead of a
    GSPMD resharding constraint.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    cf = m.capacity_factor_eval if inference else m.capacity_factor
    C = min(capacity(S, E, cf, K), S)  # C=S is provably drop-free

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                           # (B,S,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- flatten assignments and sort by expert within each group ----------
    eid = eidx.reshape(B, S * K)
    order = jnp.argsort(eid, axis=1, stable=True)                   # (B,SK)
    eids = jnp.take_along_axis(eid, order, axis=1)
    onehot = jax.nn.one_hot(eids, E, dtype=jnp.int32)               # (B,SK,E)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                               eids[..., None], axis=-1)[..., 0]    # (B,SK)
    keep = rank < C
    slot = jnp.where(keep, eids * C + rank, E * C)                  # drop row
    tok = order // K                                                # (B,SK)

    xs = jnp.take_along_axis(x, tok[..., None], axis=1)             # (B,SK,d)

    def scatter_group(slots, vals):
        buf = jnp.zeros((E * C + 1, d), vals.dtype)
        return buf.at[slots].set(vals)                              # unique slots

    buf = jax.vmap(scatter_group)(slot, xs)[:, : E * C].reshape(B, E, C, d)

    # ---- expert parallelism: reshard groups->experts (all_to_all) ----------
    bd = None
    ed = None
    expert_over_model = False
    if comm is not None:
        out_buf = _moe_experts_comm(cfg, buf, p, comm)
    elif shard is not None:
        dp = shard.dp
        tp = shard._axsize("model")
        bd = dp if B % max(1, shard._axsize(dp)) == 0 else None
        expert_over_model = ("moe_dispatch" in cfg.opts and tp > 1
                             and E % tp == 0
                             and "model" not in (dp if isinstance(dp, tuple)
                                                 else (dp,)))
        ed = dp if shard._axsize(dp) > 1 and E % shard._axsize(dp) == 0 else None
        if expert_over_model:
            # OPT(moe_dispatch)/E%tp==0: batch stays data-sharded, experts
            # shard over 'model' (weights likewise) — dispatch needs no
            # batch un-sharding; the combine gathers only out_buf shards.
            buf = shard.act(buf, bd, "model", None, None)
            ed = None
        elif ed is not None:
            # true expert parallelism: batch-sharded -> expert-sharded is
            # the GShard all_to_all (the MoE analogue of the paper's
            # parallel communication streams).
            buf = shard.act(buf, None, ed, None, None)
        elif "moe_dispatch" in cfg.opts:
            # OPT(moe_dispatch): experts don't divide the data axes (e.g.
            # mixtral's 8 on 16) — keep the dispatch buffer sharded over
            # batch groups; experts run data-parallel with TP'd hidden.
            # Baseline replicated the (B,E,C,d) buffer on every chip.
            buf = shard.act(buf, bd, None, None, None)
        else:
            buf = shard.act(buf, None, ed, None, None)

    if comm is None:
        h_bd = None if (shard is not None and ed is not None) else bd
        h = act_fn(cfg.hidden_act)(
            jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(buf.dtype))
        ) * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(buf.dtype))
        if shard is not None:
            tpff = "model" if shard.div(h.shape[-1], "model") else None
            if expert_over_model:
                h = shard.act(h, bd, "model", None, None)
            elif ed is not None:
                h = shard.act(h, None, ed, None, tpff)
            elif "moe_dispatch" in cfg.opts:
                h = shard.act(h, h_bd, None, None, tpff)
            else:
                h = shard.act(h, None, None, None, tpff)
        # preferred_element_type pins the dot's emitted dtype: without it XLA
        # accumulates the cross-shard partials in f32 and all-reduces 4-byte
        # payloads (2x link bytes) — §Perf pair 5.
        out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(h.dtype),
                             preferred_element_type=h.dtype)

        if shard is not None:
            # NOTE(§Perf pair 5, refuted): constraining out_buf's d over
            # 'model' (to turn the partial-sum AR into a reduce-scatter) makes
            # the combine gather reshard and REGRESSES 30.8s -> 57.9s.
            out_buf = shard.act(out_buf, bd, None, None, None)

    # ---- combine: gather expert outputs back to tokens ---------------------
    flat = out_buf.reshape(B, E * C, d)
    flat = jnp.concatenate([flat, jnp.zeros((B, 1, d), flat.dtype)], axis=1)
    ys = jnp.take_along_axis(flat, slot[..., None], axis=1)         # (B,SK,d)
    gv = jnp.take_along_axis(gates.reshape(B, S * K), order, axis=1)
    ys = ys * jnp.where(keep, gv, 0.0)[..., None].astype(ys.dtype)

    def combine_group(toks, vals):
        return jnp.zeros((S, d), vals.dtype).at[toks].add(vals)

    y = jax.vmap(combine_group)(tok, ys)
    if shard is not None:
        y = shard.hidden(y)

    # ---- aux losses ---------------------------------------------------------
    me = probs.mean(axis=(0, 1))                                    # (E,)
    ce = jax.nn.one_hot(eidx, E).sum(axis=2).mean(axis=(0, 1))      # fraction routed
    load_balance = E * jnp.sum(me * ce / K)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance": load_balance, "router_z": z_loss}

    if m.dense_residual:
        y = y + gated_ffn(cfg, x, p["residual"], shard, comm=comm)

    return y, aux


def _moe_experts_comm(cfg: ModelConfig, buf, p, comm):
    """Expert FFNs under the manual-TP serve path (``repro.serve.comm``).

    ``buf`` — the (B, E, C, d) dispatch buffer — is replicated over the TP
    axis (decode activations are), so the GShard dispatch all_to_all
    degenerates to a local slice: each rank keeps the rows of its own
    experts. The combine is the real collective — an all-gather of every
    rank's expert outputs on the dedicated ``moe`` VCI stream. When the
    expert count does not divide the axis the tables arrive ff-TP sharded
    instead and the combine is the partial-sum all-reduce, same stream.
    """
    E = cfg.moe.num_experts
    a = act_fn(cfg.hidden_act)
    e_loc = p["w_gate"].shape[0]         # local expert count (E or E/tp)
    if e_loc != E:
        # expert-parallel: slice this rank's experts out of the replicated
        # dispatch buffer (the decode-time dispatch), compute, all-gather.
        assert E % e_loc == 0, (E, e_loc)
        start = comm.rank() * e_loc
        buf = jax.lax.dynamic_slice_in_dim(buf, start, e_loc, axis=1)
    h = a(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(buf.dtype))
          ) * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(buf.dtype))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(h.dtype),
                         preferred_element_type=h.dtype)
    if e_loc != E:
        return comm.all_gather(out_buf, "moe", gather_axis=1)
    return comm.psum(out_buf, "moe")
