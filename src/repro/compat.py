"""JAX version compatibility shims.

The repo targets the modern ``jax.shard_map`` / ``jax.set_mesh`` surface;
older jaxlibs (e.g. 0.4.x) only ship ``jax.experimental.shard_map`` with the
``check_rep``/``auto`` spelling and no ambient-mesh setter. Every call site
imports from here so the rest of the codebase is written against ONE
(modern) API:

* :func:`shard_map` — keyword-only ``mesh``/``in_specs``/``out_specs`` plus
  ``check_vma`` (mapped to ``check_rep`` on old jax) and ``axis_names`` (the
  manual axes; mapped to the complement ``auto`` frozenset on old jax).
* :func:`set_mesh` — context manager; ``jax.set_mesh`` when present, else the
  legacy ``with mesh:`` global-mesh context (a no-op for code that passes
  meshes explicitly, which this repo does).
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional, Set

import jax

__all__ = ["axis_size", "make_mesh", "shard_map", "set_mesh",
           "tpu_compiler_params"]


def make_mesh(shape, axes, *, explicit: bool = False):
    """``jax.make_mesh`` with ``axis_types`` only where the version has it."""
    if hasattr(jax.sharding, "AxisType"):
        kind = (jax.sharding.AxisType.Explicit if explicit
                else jax.sharding.AxisType.Auto)
        return jax.make_mesh(shape, axes, axis_types=(kind,) * len(axes))
    return jax.make_mesh(shape, axes)


def axis_size(axis) -> int:
    """``lax.axis_size`` (modern) with a legacy fallback: ``psum(1, axis)``
    constant-folds to the mapped axis size inside shard_map/pmap traces."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names: Optional[Set[Any]] = None):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)

else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names: Optional[Set[Any]] = None):
        auto: frozenset = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma,
                                 auto=auto)


def set_mesh(mesh):
    """``with set_mesh(mesh): ...`` — ambient mesh on any jax version."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if mesh is None:
        return contextlib.nullcontext()
    return mesh  # Mesh is a context manager on legacy jax (global mesh)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (modern) / ``TPUCompilerParams`` (legacy)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
