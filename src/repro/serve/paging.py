"""Page allocator for the paged KV cache (serve engine).

The paged serve cache is a fixed pool of fixed-size pages plus a per-slot
page table (:class:`repro.models.attention.PagedKVCache`). This module owns
the *allocation* half of that design: a pure-JAX free-page allocator whose
state is two small int32 arrays, so every operation jits (and round-trips
through jit — the engine calls the jitted forms between decode steps
without ever synchronizing) and the arrays ride along with donated caches.

State (:class:`PageState`):

* ``table`` — ``(B, max_pages)`` int32: slot b's logical page ``p`` lives in
  pool page ``table[b, p]``; ``-1`` means unmapped (reads/writes through an
  unmapped entry are routed to the reserved trash page — see below);
* ``owner`` — ``(num_pages,)`` int32: the slot owning each pool page, ``-1``
  free, ``OWNER_RESERVED`` never allocatable.

Pool page 0 is the TRASH page (``owner[0] = OWNER_RESERVED``): finished
slots' decode writes and pad-prefix prefill writes land there, so the model
code never needs a branch for "this row has no page" — attention masks the
positions anyway. Allocation picks the LOWEST free pool ids (``jnp.nonzero``
order), which keeps the realized mapping deterministic: paged and contiguous
engines must produce identical tokens, so nothing downstream may depend on
*which* page a slot got, and the tests pin that determinism.

Capacity is the CALLER's contract: the engine reserves worst-case page
spans at batch formation / admission time, so ``alloc`` never runs out.
Each op still returns an ``ok`` flag (enough free pages existed); on
overflow the surplus updates are dropped (out-of-bounds scatter) and ``ok``
is False — callers that can't pre-reserve must check it.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

OWNER_FREE = -1
OWNER_RESERVED = -2
TRASH_PAGE = 0


class PageState(NamedTuple):
    """Allocator state; both leaves are small int32 arrays (jit-friendly)."""

    table: jax.Array   # (B, max_pages) int32 — pool page id or -1
    owner: jax.Array   # (num_pages,) int32 — owning slot, -1 free, -2 reserved


def page_state_init(num_pages: int, batch: int, max_pages: int) -> PageState:
    """Fresh state: everything unmapped, page 0 reserved as trash."""
    if num_pages < 2:
        raise ValueError(f"need >= 2 pages (1 is the trash page), got "
                         f"{num_pages}")
    table = jnp.full((batch, max_pages), -1, jnp.int32)
    owner = jnp.full((num_pages,), OWNER_FREE, jnp.int32)
    owner = owner.at[TRASH_PAGE].set(OWNER_RESERVED)
    return PageState(table, owner)


def pages_free(state: PageState) -> jax.Array:
    """() int32 — allocatable pages remaining."""
    return jnp.sum((state.owner == OWNER_FREE).astype(jnp.int32))


def pages_used(state: PageState) -> jax.Array:
    """() int32 — pages currently owned by some slot (trash excluded)."""
    return jnp.sum((state.owner >= 0).astype(jnp.int32))


def _take_free(owner: jax.Array, n: int) -> Tuple[jax.Array, jax.Array]:
    """(ids: (n,) int32 lowest free pool pages, ok: () bool).

    On shortfall the missing ids are ``num_pages`` (one past the pool), so
    the subsequent scatters drop them instead of corrupting page state.
    """
    free = owner == OWNER_FREE
    ids = jnp.nonzero(free, size=n, fill_value=owner.shape[0])[0]
    ids = ids.astype(jnp.int32)
    ok = jnp.sum(free.astype(jnp.int32)) >= n
    return ids, ok


def alloc_slot_pages(state: PageState, slot: jax.Array,
                     logical: jax.Array) -> Tuple[PageState, jax.Array]:
    """Map ``len(logical)`` fresh pool pages at ``slot``'s logical indices.

    ``logical`` — (n,) int32, n static. Returns (new state, ok). Used for
    the initial-prefill and admission-prefill ranges.

    Contract: every ``logical`` entry must currently be UNMAPPED for
    ``slot`` — remapping a mapped entry overwrites the table reference
    while the old page keeps its owner, leaking it until the next
    ``free_slot_pages``. The engine satisfies this by freeing a slot
    before re-admitting into it.
    """
    n = logical.shape[0]
    ids, ok = _take_free(state.owner, n)
    owner = state.owner.at[ids].set(jnp.asarray(slot, jnp.int32))
    # shortfall ids are out of range: the owner scatter drops them and the
    # table keeps those logical entries unmapped — a failed alloc leaves a
    # consistent (partially mapped) state
    table_ids = jnp.where(ids < state.owner.shape[0], ids, -1)
    table = state.table.at[jnp.asarray(slot, jnp.int32),
                           logical].set(table_ids)
    return PageState(table, owner), ok


def alloc_step_pages(state: PageState, slots: jax.Array,
                     logical: jax.Array) -> Tuple[PageState, jax.Array]:
    """One page per slot in ``slots`` at the SAME logical index — the decode
    page-boundary allocation (the shared write cursor crosses into logical
    page ``cur // page_size`` for every live slot at once).

    ``slots`` — (m,) int32, m static; ``logical`` — () int32. Same
    unmapped-entry contract as :func:`alloc_slot_pages`.
    """
    m = slots.shape[0]
    ids, ok = _take_free(state.owner, m)
    owner = state.owner.at[ids].set(slots.astype(jnp.int32))
    table_ids = jnp.where(ids < state.owner.shape[0], ids, -1)
    table = state.table.at[slots.astype(jnp.int32),
                           jnp.asarray(logical, jnp.int32)].set(table_ids)
    return PageState(table, owner), ok


def free_slot_pages(state: PageState, slot: jax.Array) -> PageState:
    """Reclaim every page ``slot`` owns and clear its table row — the
    per-slot compaction the paged cache gets for free: the instant a
    request finishes, its pages return to the pool."""
    slot = jnp.asarray(slot, jnp.int32)
    owner = jnp.where(state.owner == slot, OWNER_FREE, state.owner)
    table = state.table.at[slot].set(-1)
    return PageState(table, owner)


def pages_for_span(start: int, end: int, page_size: int) -> int:
    """Host-side: pages covering token positions ``[start, end)`` — the
    engine's reservation unit (worst-case span of one slot)."""
    if end <= start:
        return 0
    return (end - 1) // page_size - start // page_size + 1


# jitted forms — the engine uses these between decode steps; shapes key the
# trace cache (n distinct range sizes / live-slot counts stay small).
alloc_slot_pages_jit = jax.jit(alloc_slot_pages)
alloc_step_pages_jit = jax.jit(alloc_step_pages)
free_slot_pages_jit = jax.jit(free_slot_pages)
