from repro.serve.comm import PURPOSES, ServeComm, ServeCommPlan
from repro.serve.engine import (
    Request,
    ServeEngine,
    greedy_sample,
    make_prefill,
    make_serve_step,
    select_tokens,
    temperature_sample,
)

__all__ = [
    "PURPOSES", "Request", "ServeComm", "ServeCommPlan", "ServeEngine",
    "greedy_sample", "make_prefill", "make_serve_step", "select_tokens",
    "temperature_sample",
]
