from repro.serve.engine import ServeEngine, greedy_sample, make_serve_step

__all__ = ["ServeEngine", "greedy_sample", "make_serve_step"]
