from repro.serve.comm import PURPOSES, ServeComm, ServeCommPlan
from repro.serve.engine import (
    Request,
    ServeEngine,
    greedy_sample,
    make_prefill,
    make_serve_step,
    select_tokens,
    temperature_sample,
)
from repro.serve.paging import (
    PageState,
    alloc_slot_pages,
    alloc_step_pages,
    free_slot_pages,
    page_state_init,
    pages_for_span,
)

__all__ = [
    "PURPOSES", "PageState", "Request", "ServeComm", "ServeCommPlan",
    "ServeEngine", "alloc_slot_pages", "alloc_step_pages",
    "free_slot_pages", "greedy_sample", "make_prefill", "make_serve_step",
    "page_state_init", "pages_for_span", "select_tokens",
    "temperature_sample",
]
