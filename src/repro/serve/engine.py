"""Serving: prefill + batched decode with KV/SSM caches.

``make_serve_step`` builds the one-token decode function the dry-run lowers
for the decode shapes (``decode_32k``, ``long_500k``): ONE new token against
a ``seq_len``-deep cache.

``ServeEngine`` is the host-side loop: batched requests, prefill, iterative
greedy/temperature decoding, and per-request stop handling — a deliberately
small continuous-batching core (static batch, replace-on-finish).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import Sharder
from repro.models.transformer import DecodeCache, Model, init_cache


def greedy_sample(logits: jax.Array) -> jax.Array:
    """logits: (B, 1, V) or (B, K, 1, V) -> next token ids."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits, temperature: float = 1.0):
    return jax.random.categorical(key, logits / max(temperature, 1e-4)
                                  ).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, mesh=None
                    ) -> Callable[[Any, jax.Array, DecodeCache], Tuple]:
    """Returns ``serve_step(params, tokens, cache) -> (next_tokens, cache)``.

    tokens: (B,1) int32 (or (B,K,1) audio). This is the function the decode
    dry-run shapes lower.
    """
    shard = Sharder(mesh, cfg) if mesh is not None else None
    model = Model(cfg, shard)

    def serve_step(params, tokens, cache: DecodeCache):
        logits, new_cache = model.decode_step(params, tokens, cache)
        nxt = greedy_sample(logits)
        return nxt, new_cache

    return serve_step


def make_prefill(cfg: ModelConfig, mesh=None):
    shard = Sharder(mesh, cfg) if mesh is not None else None
    model = Model(cfg, shard)

    def prefill(params, batch, cache: DecodeCache):
        logits, _, new_cache = model.forward(params, batch, cache=cache)
        if cfg.modality == "audio":
            nxt = greedy_sample(logits[..., -1:, :])
        else:
            nxt = greedy_sample(logits[:, -1:, :])
        return nxt, new_cache

    return prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) or (K,S) token ids
    max_new_tokens: int = 32
    generated: Optional[np.ndarray] = None


class ServeEngine:
    """Static-batch serving loop with greedy decoding."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_len: int, mesh=None, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill(cfg, mesh))
        self._step = jax.jit(make_serve_step(cfg, mesh), donate_argnums=(2,))
        self._cache_dtype = cache_dtype

    def generate(self, requests: List[Request]) -> List[Request]:
        cfg = self.cfg
        out: List[Request] = []
        for i in range(0, len(requests), self.batch_size):
            out.extend(self._run_batch(requests[i: i + self.batch_size]))
        return out

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        cfg = self.cfg
        b = len(reqs)
        plen = min(min(r.prompt.shape[-1] for r in reqs), self.max_len - 1)
        prompts = np.stack([r.prompt[..., :plen] for r in reqs])
        cache = init_cache(cfg, b, self.max_len, dtype=self._cache_dtype)
        batch = {"tokens": jnp.asarray(prompts)}
        nxt, cache = self._prefill(self.params, batch, cache)
        steps = max(r.max_new_tokens for r in reqs)
        gen = [np.asarray(nxt)]
        for _ in range(steps - 1):
            nxt, cache = self._step(self.params, nxt, cache)
            gen.append(np.asarray(nxt))
        toks = np.concatenate(gen, axis=-1)  # (B,steps) or (B,K,steps)
        for i, r in enumerate(reqs):
            r.generated = toks[i][..., : r.max_new_tokens]
        return reqs
