"""Serving: prefill + batched decode with KV/SSM caches.

``make_serve_step`` builds the one-token decode function the dry-run lowers
for the decode shapes (``decode_32k``, ``long_500k``): ONE new token against
a ``seq_len``-deep cache. With a :class:`~repro.serve.comm.ServeCommPlan`
it instead builds the manual-TP step whose collectives (attention/FFN
partial sums, MoE combine, vocab-parallel sampling gather) each ride their
own CommContext/VCI stream — the serve-side analogue of the gradient
bucketing path.

``ServeEngine`` is the host-side continuous-batching loop:

* mixed-length prompts are LEFT-padded to a common width and prefilled with
  per-row pad masks + shifted RoPE positions, so a request's tokens are
  identical no matter what it is batched with (the old engine truncated the
  batch to the shortest prompt);
* greedy or per-request temperature sampling, per-request ``stop_token``
  and ``max_new_tokens``;
* early slot recycling: a finished slot is re-filled mid-stream by
  prefilling the next request's prompt into the cache rows just below the
  shared write cursor (its ``start`` offset masks everything older);
* ``generate()`` validates ``prompt_len + max_new_tokens <= max_len`` up
  front — decode can never write past the cache depth.

Architectures whose decode state cannot be pad-masked per row (SSM/hybrid
recurrences, ring caches, VLM/audio frontends) fall back to equal-length
grouped batches — same results, no corruption, just less packing.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import set_mesh, shard_map
from repro.configs.base import ModelConfig
from repro.dist.sharding import Sharder, batch_axes
from repro.models.attention import KVCache
from repro.models.transformer import DecodeCache, Model, init_cache
from repro.serve.comm import (
    TP_AXIS,
    ServeCommPlan,
    serve_cache_specs,
    serve_param_specs,
    serve_tp_validate,
)


def greedy_sample(logits: jax.Array) -> jax.Array:
    """logits: (B, 1, V) or (B, K, 1, V) -> next token ids."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits, temperature: float = 1.0):
    return jax.random.categorical(key, logits / max(temperature, 1e-4)
                                  ).astype(jnp.int32)


def select_tokens(logits, temps=None, key=None) -> jax.Array:
    """Greedy/temperature sampling with PER-ROW temperatures.

    ``temps`` — (B,) float32; rows with ``temp <= 0`` take the argmax, rows
    with ``temp > 0`` sample from the tempered categorical. ``temps=None``
    is pure greedy (and needs no key). logits: (B, 1, V) or (B, K, 1, V).
    """
    greedy = greedy_sample(logits)
    if temps is None:
        return greedy
    if key is None:
        raise ValueError("select_tokens: temps given without a PRNG key — "
                         "pass key=... or temps=None for greedy")
    b = logits.shape[0]
    t = temps.reshape((b,) + (1,) * (logits.ndim - 1 - 1))
    sampled = jax.random.categorical(
        key, logits / jnp.maximum(t, 1e-4)[..., None]).astype(jnp.int32)
    use = (temps > 0).reshape((b,) + (1,) * (greedy.ndim - 1))
    return jnp.where(use, sampled, greedy)


def _last_logits(cfg: ModelConfig, logits):
    if cfg.modality == "audio":
        return logits[..., -1:, :]
    return logits[:, -1:, :]


def make_serve_step(cfg: ModelConfig, mesh=None, comm_plan=None, lane: int = 0
                    ) -> Callable[..., Tuple]:
    """Returns ``serve_step(params, tokens, cache, start=None, temps=None,
    key=None) -> (next_tokens, cache)``.

    tokens: (B,1) int32 (or (B,K,1) audio). This is the function the decode
    dry-run shapes lower. ``comm_plan`` selects the manual-TP VCI-stream
    path (see :mod:`repro.serve.comm`).
    """
    if comm_plan is not None:
        return _make_serve_step_comm(cfg, mesh, comm_plan, lane)
    shard = Sharder(mesh, cfg) if mesh is not None else None
    model = Model(cfg, shard)

    def serve_step(params, tokens, cache: DecodeCache, start=None,
                   temps=None, key=None):
        logits, new_cache = model.decode_step(params, tokens, cache,
                                              start=start)
        nxt = select_tokens(logits, temps, key)
        return nxt, new_cache

    return serve_step


def make_prefill(cfg: ModelConfig, mesh=None, comm_plan=None, lane: int = 0):
    """Returns ``prefill(params, batch, cache, start=None, temps=None,
    key=None) -> (next_tokens, cache)`` sampling the first new token."""
    if comm_plan is not None:
        return _make_prefill_comm(cfg, mesh, comm_plan, lane)
    shard = Sharder(mesh, cfg) if mesh is not None else None
    model = Model(cfg, shard)

    def prefill(params, batch, cache: DecodeCache, start=None, temps=None,
                key=None):
        logits, _, new_cache = model.forward(params, batch, cache=cache,
                                             start=start)
        nxt = select_tokens(_last_logits(cfg, logits), temps, key)
        return nxt, new_cache

    return prefill


# ---------------------------------------------------------------------------
# the manual-TP (VCI stream) step builders
# ---------------------------------------------------------------------------

def _mesh_tp(mesh) -> int:
    return dict(mesh.shape).get(TP_AXIS, 1)


def _mesh_batch(mesh) -> Tuple[Any, int]:
    """(spec entry, shard count) for the batch dim over the non-TP axes."""
    dp = batch_axes(mesh)
    n = 1
    for a in dp:
        n *= dict(mesh.shape)[a]
    return (dp[0] if len(dp) == 1 else tuple(dp)), n


def _make_serve_step_comm(cfg: ModelConfig, mesh, comm_plan: ServeCommPlan,
                          lane: int):
    assert mesh is not None, "comm_plan needs a mesh with a 'model' axis"
    tp = _mesh_tp(mesh)
    serve_tp_validate(cfg, tp)
    dpe, nb = _mesh_batch(mesh)

    def serve_step(params, tokens, cache, start, temps, key):
        bd = dpe if (nb > 1 and tokens.shape[0] % nb == 0) else None
        nshard = nb if bd is not None else 1

        def inner(params, tokens, cache, start, temps, key):
            comm = comm_plan.comm(lane)
            model = Model(cfg, None, comm=comm)
            logits, new_cache = model.decode_step(params, tokens, cache,
                                                  start=start)
            logits = comm.drain(logits)
            return select_tokens(logits, temps, key), new_cache

        cspec = serve_cache_specs(cache, tp, nshard, batch_axis=dpe)
        f = shard_map(
            inner, mesh=mesh,
            in_specs=(serve_param_specs(cfg, params, tp), P(bd, None),
                      cspec, P(bd), P(bd), P()),
            out_specs=(P(bd, None), cspec),
            check_vma=False, axis_names=set(mesh.axis_names))
        return f(params, tokens, cache, start, temps, key)

    return serve_step


def _make_prefill_comm(cfg: ModelConfig, mesh, comm_plan: ServeCommPlan,
                       lane: int):
    assert mesh is not None, "comm_plan needs a mesh with a 'model' axis"
    tp = _mesh_tp(mesh)
    serve_tp_validate(cfg, tp)
    dpe, nb = _mesh_batch(mesh)

    def prefill(params, batch, cache, start, temps, key):
        tokens = batch["tokens"]
        bd = dpe if (nb > 1 and tokens.shape[0] % nb == 0) else None
        nshard = nb if bd is not None else 1

        def inner(params, batch, cache, start, temps, key):
            comm = comm_plan.comm(lane)
            model = Model(cfg, None, comm=comm)
            logits, _, new_cache = model.forward(params, batch, cache=cache,
                                                 start=start)
            logits = comm.drain(logits)
            nxt = select_tokens(_last_logits(cfg, logits), temps, key)
            return nxt, new_cache

        cspec = serve_cache_specs(cache, tp, nshard, batch_axis=dpe)
        f = shard_map(
            inner, mesh=mesh,
            in_specs=(serve_param_specs(cfg, params, tp),
                      {"tokens": P(bd, None)},
                      cspec, P(bd), P(bd), P()),
            out_specs=(P(bd, None), cspec),
            check_vma=False, axis_names=set(mesh.axis_names))
        return f(params, batch, cache, start, temps, key)

    return prefill


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    prompt: np.ndarray                    # (S,) or (K,S) token ids
    max_new_tokens: int = 32
    temperature: Optional[float] = None   # None -> engine default; 0 = greedy
    stop_token: Optional[int] = None      # finish early when sampled
    generated: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = True

    def activate(self, req: Request):
        self.req, self.tokens, self.done = req, [], False

    def finish(self):
        self.done = True
        if self.req is not None:
            self.req.generated = np.asarray(self.tokens, np.int32)


_ADMIT_ALIGN = 8  # admission prompts pad to multiples of this (fewer traces)


class ServeEngine:
    """Continuous-batching serving loop (see module docstring).

    ``mesh`` + ``comm_plan`` (or ``num_vcis``) select the manual-TP decode
    whose collectives ride per-purpose VCI streams; with ``mesh=None`` the
    same loop runs single-device. Early slot recycling (mid-stream
    admission) is host-driven and currently single-device only.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_len: int, mesh=None, cache_dtype=jnp.float32,
                 comm_plan: Optional[ServeCommPlan] = None,
                 num_vcis: Optional[int] = None, vci_policy: str = "fcfs",
                 progress: str = "hybrid", token_impl: str = "barrier",
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.mesh = mesh
        self.temperature = temperature
        if comm_plan is None and num_vcis is not None:
            if mesh is None or _mesh_tp(mesh) <= 1:
                raise ValueError("num_vcis needs a mesh with a 'model' axis "
                                 ">1 (the TP streams live there)")
            comm_plan = ServeCommPlan(num_vcis=num_vcis,
                                      vci_policy=vci_policy,
                                      progress=progress,
                                      token_impl=token_impl)
        self.comm_plan = comm_plan
        self._prefill = jax.jit(make_prefill(cfg, mesh, comm_plan))
        self._step = jax.jit(make_serve_step(cfg, mesh, comm_plan),
                             donate_argnums=(2,))
        self._admit_fns: Dict[int, Callable] = {}
        self._cache_dtype = cache_dtype
        self._key = jax.random.PRNGKey(seed)
        self._nkey = 0
        self._ring = (cfg.sliding_window is not None
                      and cfg.sliding_window < max_len)
        # left-padded mixed-length batching needs per-row attention masks;
        # SSM/hybrid state, ring caches and non-text frontends can't provide
        # them -> equal-length grouped batches for those.
        self._padded_ok = (cfg.family in ("dense", "moe")
                           and cfg.modality == "text" and not self._ring)
        # mid-stream admission re-prefills single requests; keep it off the
        # sharded path (B=1 doesn't shard over the data axes).
        self._can_admit = mesh is None

    # -- small helpers ---------------------------------------------------
    def _next_key(self):
        self._nkey += 1
        return jax.random.fold_in(self._key, self._nkey)

    def _temp_of(self, r: Request) -> float:
        return self.temperature if r.temperature is None else r.temperature

    def _validate(self, requests: List[Request]) -> None:
        for i, r in enumerate(requests):
            plen = int(r.prompt.shape[-1])
            if plen < 1:
                raise ValueError(f"request {i}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {i}: max_new_tokens < 1")
            if plen + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {i}: prompt_len {plen} + max_new_tokens "
                    f"{r.max_new_tokens} exceeds the cache depth "
                    f"(max_len={self.max_len}); decode would write past the "
                    f"cache — shorten the request or raise max_len")

    # -- public API ------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        self._validate(requests)
        ctx = (set_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            if self._padded_ok:
                pending = list(requests)
                while pending:
                    batch = self._take_batch(pending)
                    self._run_continuous(batch, pending)
            else:
                # grouped fallback: equal prompt lengths per batch
                groups: Dict[int, List[Request]] = {}
                for r in requests:
                    groups.setdefault(int(r.prompt.shape[-1]), []).append(r)
                for _, rs in sorted(groups.items()):
                    for i in range(0, len(rs), self.batch_size):
                        self._run_grouped(rs[i: i + self.batch_size])
        return requests

    # -- batch formation -------------------------------------------------
    def _take_batch(self, pending: List[Request]) -> List[Request]:
        """Pop up to ``batch_size`` requests whose LEFT-PADDED runway fits:
        with pad width P = max(prompt lens), every member still needs
        ``P + max_new <= max_len`` (padding consumes cache depth)."""
        batch: List[Request] = []
        pad = 0
        i = 0
        while i < len(pending) and len(batch) < self.batch_size:
            r = pending[i]
            p_new = max(pad, int(r.prompt.shape[-1]))
            if all(p_new + q.max_new_tokens <= self.max_len
                   for q in batch + [r]):
                batch.append(pending.pop(i))
                pad = p_new
            else:
                i += 1
        assert batch, "a validated request always fits alone"
        return batch

    # -- continuous (left-padded) path ------------------------------------
    def _run_continuous(self, batch: List[Request],
                        pending: List[Request]) -> None:
        cfg = self.cfg
        B = self.batch_size
        slots = [_Slot() for _ in range(B)]
        for s, r in zip(slots, batch):
            s.activate(r)
        plens = [int(s.req.prompt.shape[-1]) if s.req is not None
                 else int(batch[0].prompt.shape[-1]) for s in slots]
        pad = max(plens)
        tokens = np.zeros((B, pad), np.int32)
        for i, s in enumerate(slots):
            prm = (s.req or batch[0]).prompt
            tokens[i, pad - plens[i]:] = prm
        start = np.asarray([pad - p for p in plens], np.int32)
        temps = np.asarray([self._temp_of(s.req) if s.req else 0.0
                            for s in slots], np.float32)
        cache = init_cache(cfg, B, self.max_len, dtype=self._cache_dtype)
        nxt, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens)}, cache,
            jnp.asarray(start), jnp.asarray(temps), self._next_key())
        cur = pad

        def record(s: _Slot, t: int) -> None:
            if s.req.stop_token is not None and t == s.req.stop_token:
                s.finish()
                return
            s.tokens.append(t)
            if len(s.tokens) >= s.req.max_new_tokens:
                s.finish()

        while True:
            toks = np.array(nxt)  # copy: admission may overwrite a row
            admitted = False
            for i, s in enumerate(slots):
                if not s.done and s.req is not None:
                    record(s, int(toks[i, 0]))
            # early slot recycling: prefill the next request into a finished
            # slot just below the shared cursor (start masks older rows)
            if self._can_admit and pending:
                for i, s in enumerate(slots):
                    if not s.done or not pending:
                        continue
                    j = self._admittable(pending, cur)
                    if j is None:
                        continue
                    r = pending.pop(j)
                    tok0, cache = self._admit(r, cache, i, cur)
                    s.activate(r)
                    start[i] = cur - int(r.prompt.shape[-1])
                    temps[i] = self._temp_of(r)
                    toks[i, 0] = tok0
                    record(s, tok0)  # the admission prefill's first token
                    admitted = True
            if all(s.done or s.req is None for s in slots):
                break
            if admitted:
                nxt = jnp.asarray(toks)
            if cur >= self.max_len:  # defensive: budgets guarantee this
                for s in slots:      # never trips (validated runways)
                    if not s.done:
                        s.finish()
                break
            nxt, cache = self._step(self.params, nxt, cache,
                                    jnp.asarray(start), jnp.asarray(temps),
                                    self._next_key())
            cur += 1

    def _admittable(self, pending: List[Request], cur: int) -> Optional[int]:
        """Index of the first pending request that fits at cursor ``cur``:
        its prompt must fit below the cursor and its token budget inside the
        remaining cache depth."""
        for j, r in enumerate(pending):
            plen = int(r.prompt.shape[-1])
            if plen <= cur and cur + r.max_new_tokens <= self.max_len:
                return j
        return None

    def _admit(self, r: Request, cache, slot: int, cur: int):
        """Prefill ``r`` alone and splice its KV rows into ``cache[slot]``
        at ``[cur - plen, cur)``; returns (first token, cache)."""
        plen = int(r.prompt.shape[-1])
        p_adm = min(-(-plen // _ADMIT_ALIGN) * _ADMIT_ALIGN, cur)
        fn = self._admit_fn(p_adm)
        tokens = np.zeros((1, p_adm), np.int32)
        tokens[0, p_adm - plen:] = r.prompt
        nxt, cache = fn(self.params, jnp.asarray(tokens), cache,
                        jnp.asarray(slot, jnp.int32),
                        jnp.asarray(cur - p_adm, jnp.int32),
                        jnp.asarray([p_adm - plen], jnp.int32),
                        jnp.asarray([self._temp_of(r)], jnp.float32),
                        self._next_key())
        return int(np.asarray(nxt)[0, 0]), cache

    def _admit_fn(self, p_adm: int):
        """Jitted single-request admission prefill, cached per padded
        prompt width (widths are rounded to ``_ADMIT_ALIGN`` to bound the
        number of traces)."""
        fn = self._admit_fns.get(p_adm)
        if fn is not None:
            return fn
        cfg = self.cfg
        model = Model(cfg)

        def admit(params, tokens, cache, slot, dest, start1, temp1, key):
            tmp = init_cache(cfg, 1, tokens.shape[1],
                             dtype=self._cache_dtype)
            logits, _, tmp = model.forward(params, {"tokens": tokens},
                                           cache=tmp, start=start1)
            nxt = select_tokens(_last_logits(cfg, logits), temp1, key)
            k = jax.lax.dynamic_update_slice(
                cache.kv.k, tmp.kv.k.astype(cache.kv.k.dtype),
                (0, slot, dest, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache.kv.v, tmp.kv.v.astype(cache.kv.v.dtype),
                (0, slot, dest, 0, 0))
            new_cache = DecodeCache(
                KVCache(k, v, cache.kv.length, cache.kv.ring), cache.ssm,
                cache.length)
            return nxt, new_cache

        fn = jax.jit(admit, donate_argnums=(2,))
        self._admit_fns[p_adm] = fn
        return fn

    # -- grouped (equal prompt length) fallback ---------------------------
    def _run_grouped(self, reqs: List[Request]) -> None:
        cfg = self.cfg
        b = len(reqs)
        prompts = np.stack([r.prompt for r in reqs])
        cache = init_cache(cfg, b, self.max_len, dtype=self._cache_dtype)
        temps = np.asarray([self._temp_of(r) for r in reqs], np.float32)
        # comm-mode step functions take concrete (all-zero) start offsets;
        # the plain path keeps None (SSM/audio reject per-row offsets).
        start = (None if self.comm_plan is None
                 else jnp.zeros((b,), jnp.int32))
        nxt, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, cache, start,
            jnp.asarray(temps), self._next_key())
        text = cfg.modality == "text"
        gen = [np.asarray(nxt)]
        stopped = [False] * b

        def update_stops():
            if not text:
                return
            for i, r in enumerate(reqs):
                if r.stop_token is not None and \
                        int(gen[-1][i, 0]) == r.stop_token:
                    stopped[i] = True

        update_stops()
        while any(not stopped[i] and len(gen) < r.max_new_tokens
                  for i, r in enumerate(reqs)):
            nxt, cache = self._step(self.params, nxt, cache, start,
                                    jnp.asarray(temps), self._next_key())
            gen.append(np.asarray(nxt))
            update_stops()
        toks = np.concatenate(gen, axis=-1)  # (B,steps) or (B,K,steps)
        for i, r in enumerate(reqs):
            seq = toks[i][..., : r.max_new_tokens]
            if text and r.stop_token is not None:
                hits = np.nonzero(seq == r.stop_token)[0]
                if hits.size:
                    seq = seq[: int(hits[0])]
            r.generated = seq
