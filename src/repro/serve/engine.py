"""Serving: prefill + batched decode with KV/SSM caches.

``make_serve_step`` builds the one-token decode function the dry-run lowers
for the decode shapes (``decode_32k``, ``long_500k``): ONE new token against
a ``seq_len``-deep cache. With a :class:`~repro.serve.comm.ServeCommPlan`
it instead builds the manual-TP step whose collectives (attention/FFN
partial sums, MoE combine, vocab-parallel sampling gather) each ride their
own CommContext/VCI stream — the serve-side analogue of the gradient
bucketing path.

``ServeEngine`` is the host-side continuous-batching loop:

* mixed-length prompts are LEFT-padded to a common width and prefilled with
  per-row pad masks + shifted RoPE positions, so a request's tokens are
  identical no matter what it is batched with (the old engine truncated the
  batch to the shortest prompt);
* greedy or per-request temperature sampling, per-request ``stop_token``
  and ``max_new_tokens``;
* early slot recycling: a finished slot is re-filled mid-stream by
  prefilling the next request's prompt into the cache rows just below the
  shared write cursor (its ``start`` offset masks everything older);
* ``generate()`` validates ``prompt_len + max_new_tokens <= max_len`` up
  front — decode can never write past the cache depth.

``paged=True`` replaces the contiguous cache with the PAGED KV cache
(:class:`~repro.models.attention.PagedKVCache` + the pure-JAX allocator in
:mod:`repro.serve.paging`): a finished slot's pages are reclaimed the
moment it finishes, and mid-stream admission works under a mesh because
the admitted request prefills into freshly allocated pages under the same
TP specs as the running batch. See the :class:`ServeEngine` docstring.

Architectures whose decode state cannot be pad-masked per row (SSM/hybrid
recurrences, ring caches, VLM/audio frontends) fall back to equal-length
grouped batches — same results, no corruption, just less packing.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import set_mesh, shard_map
from repro.configs.base import ModelConfig
from repro.dist.sharding import Sharder, batch_axes
from repro.models.attention import KVCache, PagedKVCache, paged_splice
from repro.models.transformer import (
    DecodeCache,
    Model,
    init_cache,
    init_paged_cache,
)
from repro.serve.comm import (
    TP_AXIS,
    ServeCommPlan,
    serve_cache_specs,
    serve_param_specs,
    serve_tp_validate,
)
from repro.serve.paging import (
    PageState,
    alloc_slot_pages_jit,
    alloc_step_pages_jit,
    free_slot_pages_jit,
    page_state_init,
    pages_for_span,
)


def greedy_sample(logits: jax.Array) -> jax.Array:
    """logits: (B, 1, V) or (B, K, 1, V) -> next token ids."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits, temperature: float = 1.0):
    return jax.random.categorical(key, logits / max(temperature, 1e-4)
                                  ).astype(jnp.int32)


def select_tokens(logits, temps=None, key=None) -> jax.Array:
    """Greedy/temperature sampling with PER-ROW temperatures.

    ``temps`` — (B,) float32; rows with ``temp <= 0`` take the argmax, rows
    with ``temp > 0`` sample from the tempered categorical. ``temps=None``
    is pure greedy (and needs no key). logits: (B, 1, V) or (B, K, 1, V).
    """
    greedy = greedy_sample(logits)
    if temps is None:
        return greedy
    if key is None:
        raise ValueError("select_tokens: temps given without a PRNG key — "
                         "pass key=... or temps=None for greedy")
    b = logits.shape[0]
    t = temps.reshape((b,) + (1,) * (logits.ndim - 1 - 1))
    sampled = jax.random.categorical(
        key, logits / jnp.maximum(t, 1e-4)[..., None]).astype(jnp.int32)
    use = (temps > 0).reshape((b,) + (1,) * (greedy.ndim - 1))
    return jnp.where(use, sampled, greedy)


def _last_logits(cfg: ModelConfig, logits):
    if cfg.modality == "audio":
        return logits[..., -1:, :]
    return logits[:, -1:, :]


def make_serve_step(cfg: ModelConfig, mesh=None, comm_plan=None, lane: int = 0
                    ) -> Callable[..., Tuple]:
    """Returns ``serve_step(params, tokens, cache, start=None, temps=None,
    key=None) -> (next_tokens, cache)``.

    tokens: (B,1) int32 (or (B,K,1) audio). This is the function the decode
    dry-run shapes lower. ``comm_plan`` selects the manual-TP VCI-stream
    path (see :mod:`repro.serve.comm`).
    """
    if comm_plan is not None:
        return _make_serve_step_comm(cfg, mesh, comm_plan, lane)
    shard = Sharder(mesh, cfg) if mesh is not None else None
    model = Model(cfg, shard)

    def serve_step(params, tokens, cache: DecodeCache, start=None,
                   temps=None, key=None):
        logits, new_cache = model.decode_step(params, tokens, cache,
                                              start=start)
        nxt = select_tokens(logits, temps, key)
        return nxt, new_cache

    return serve_step


def make_prefill(cfg: ModelConfig, mesh=None, comm_plan=None, lane: int = 0):
    """Returns ``prefill(params, batch, cache, start=None, temps=None,
    key=None) -> (next_tokens, cache)`` sampling the first new token."""
    if comm_plan is not None:
        return _make_prefill_comm(cfg, mesh, comm_plan, lane)
    shard = Sharder(mesh, cfg) if mesh is not None else None
    model = Model(cfg, shard)

    def prefill(params, batch, cache: DecodeCache, start=None, temps=None,
                key=None):
        logits, _, new_cache = model.forward(params, batch, cache=cache,
                                             start=start)
        nxt = select_tokens(_last_logits(cfg, logits), temps, key)
        return nxt, new_cache

    return prefill


# ---------------------------------------------------------------------------
# the manual-TP (VCI stream) step builders
# ---------------------------------------------------------------------------

def _mesh_tp(mesh) -> int:
    return dict(mesh.shape).get(TP_AXIS, 1)


def _mesh_batch(mesh) -> Tuple[Any, int]:
    """(spec entry, shard count) for the batch dim over the non-TP axes."""
    dp = batch_axes(mesh)
    n = 1
    for a in dp:
        n *= dict(mesh.shape)[a]
    return (dp[0] if len(dp) == 1 else tuple(dp)), n


def _make_serve_step_comm(cfg: ModelConfig, mesh, comm_plan: ServeCommPlan,
                          lane: int):
    assert mesh is not None, "comm_plan needs a mesh with a 'model' axis"
    tp = _mesh_tp(mesh)
    serve_tp_validate(cfg, tp)
    dpe, nb = _mesh_batch(mesh)

    def serve_step(params, tokens, cache, start, temps, key):
        # the paged pool is a shared resource (any slot <-> any page): it
        # replicates over the data axes, so the batch does too.
        paged = isinstance(cache.kv, PagedKVCache)
        bd = dpe if (not paged and nb > 1
                     and tokens.shape[0] % nb == 0) else None
        nshard = nb if bd is not None else 1

        def inner(params, tokens, cache, start, temps, key):
            comm = comm_plan.comm(lane)
            model = Model(cfg, None, comm=comm)
            logits, new_cache = model.decode_step(params, tokens, cache,
                                                  start=start)
            logits = comm.drain(logits)
            return select_tokens(logits, temps, key), new_cache

        cspec = serve_cache_specs(cache, tp, nshard, batch_axis=dpe)
        f = shard_map(
            inner, mesh=mesh,
            in_specs=(serve_param_specs(cfg, params, tp), P(bd, None),
                      cspec, P(bd), P(bd), P()),
            out_specs=(P(bd, None), cspec),
            check_vma=False, axis_names=set(mesh.axis_names))
        return f(params, tokens, cache, start, temps, key)

    return serve_step


def _make_prefill_comm(cfg: ModelConfig, mesh, comm_plan: ServeCommPlan,
                       lane: int):
    assert mesh is not None, "comm_plan needs a mesh with a 'model' axis"
    tp = _mesh_tp(mesh)
    serve_tp_validate(cfg, tp)
    dpe, nb = _mesh_batch(mesh)

    def prefill(params, batch, cache, start, temps, key):
        tokens = batch["tokens"]
        paged = isinstance(cache.kv, PagedKVCache)
        bd = dpe if (not paged and nb > 1
                     and tokens.shape[0] % nb == 0) else None
        nshard = nb if bd is not None else 1

        def inner(params, batch, cache, start, temps, key):
            comm = comm_plan.comm(lane)
            model = Model(cfg, None, comm=comm)
            logits, _, new_cache = model.forward(params, batch, cache=cache,
                                                 start=start)
            logits = comm.drain(logits)
            nxt = select_tokens(_last_logits(cfg, logits), temps, key)
            return nxt, new_cache

        cspec = serve_cache_specs(cache, tp, nshard, batch_axis=dpe)
        f = shard_map(
            inner, mesh=mesh,
            in_specs=(serve_param_specs(cfg, params, tp),
                      {"tokens": P(bd, None)},
                      cspec, P(bd), P(bd), P()),
            out_specs=(P(bd, None), cspec),
            check_vma=False, axis_names=set(mesh.axis_names))
        return f(params, batch, cache, start, temps, key)

    return prefill


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    prompt: np.ndarray                    # (S,) or (K,S) token ids
    max_new_tokens: int = 32
    temperature: Optional[float] = None   # None -> engine default; 0 = greedy
    stop_token: Optional[int] = None      # finish early when sampled
    generated: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = True

    def activate(self, req: Request):
        self.req, self.tokens, self.done = req, [], False

    def finish(self):
        self.done = True
        if self.req is not None:
            self.req.generated = np.asarray(self.tokens, np.int32)


_ADMIT_ALIGN = 8  # admission prompts pad to multiples of this (fewer traces)


class ServeEngine:
    """Continuous-batching serving loop (see module docstring).

    ``mesh`` + ``comm_plan`` (or ``num_vcis``) select the manual-TP decode
    whose collectives ride per-purpose VCI streams; with ``mesh=None`` the
    same loop runs single-device.

    ``paged=True`` swaps the contiguous left-padded cache for the paged KV
    cache: a fixed pool of ``num_pages`` pages of ``page_size`` tokens plus
    a per-slot page table (:class:`~repro.models.attention.PagedKVCache`,
    allocation in :mod:`repro.serve.paging`). Two limits of the contiguous
    layout fall away:

    * a finished slot's pages return to the pool IMMEDIATELY (per-slot
      compaction for free), so ``num_pages`` can be sized to the live-token
      budget instead of ``batch * max_len`` — lower resident cache bytes at
      equal tokens;
    * mid-stream admission works under a mesh: the admitted request
      prefills into freshly allocated pages via the SAME mesh/TP specs as
      the running batch (the contiguous engine can only splice-admit
      single-device).

    Ring (sliding-window) and SSM/hybrid/audio/VLM caches have no paged
    layout; those keep the grouped equal-length contiguous fallback.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_len: int, mesh=None, cache_dtype=jnp.float32,
                 comm_plan: Optional[ServeCommPlan] = None,
                 num_vcis: Optional[int] = None, vci_policy: str = "fcfs",
                 progress: str = "hybrid", token_impl: str = "barrier",
                 temperature: float = 0.0, seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.mesh = mesh
        self.temperature = temperature
        if comm_plan is None and num_vcis is not None:
            if mesh is None or _mesh_tp(mesh) <= 1:
                raise ValueError("num_vcis needs a mesh with a 'model' axis "
                                 ">1 (the TP streams live there)")
            comm_plan = ServeCommPlan(num_vcis=num_vcis,
                                      vci_policy=vci_policy,
                                      progress=progress,
                                      token_impl=token_impl)
        self.comm_plan = comm_plan
        self._prefill = jax.jit(make_prefill(cfg, mesh, comm_plan))
        self._step = jax.jit(make_serve_step(cfg, mesh, comm_plan),
                             donate_argnums=(2,))
        self._admit_fns: Dict[int, Callable] = {}
        self._cache_dtype = cache_dtype
        self._key = jax.random.PRNGKey(seed)
        self._nkey = 0
        self._ring = (cfg.sliding_window is not None
                      and cfg.sliding_window < max_len)
        # left-padded mixed-length batching needs per-row attention masks;
        # SSM/hybrid state, ring caches and non-text frontends can't provide
        # them -> equal-length grouped batches for those.
        self._padded_ok = (cfg.family in ("dense", "moe")
                           and cfg.modality == "text" and not self._ring)
        # paged cache: attention archs on the continuous path only; other
        # families keep the grouped contiguous fallback.
        self._paged = bool(paged) and self._padded_ok
        self._page_size = int(page_size)
        self._max_pages = -(-max_len // self._page_size)
        self._num_pages = (1 + batch_size * self._max_pages
                           if num_pages is None else int(num_pages))
        if self._paged and self._num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is the trash "
                             f"page), got {self._num_pages}")
        # mid-stream admission re-prefills single requests. The contiguous
        # splice is single-device only (B=1 doesn't shard over the data
        # axes); the PAGED admission prefill runs replicated over data under
        # the running batch's TP specs, so it works on any mesh.
        self._can_admit = mesh is None or self._paged
        self.cache_bytes_resident = 0

    # -- small helpers ---------------------------------------------------
    def _next_key(self):
        self._nkey += 1
        return jax.random.fold_in(self._key, self._nkey)

    def _temp_of(self, r: Request) -> float:
        return self.temperature if r.temperature is None else r.temperature

    def _validate(self, requests: List[Request]) -> None:
        for i, r in enumerate(requests):
            plen = int(r.prompt.shape[-1])
            if plen < 1:
                raise ValueError(f"request {i}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {i}: max_new_tokens < 1")
            if plen + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {i}: prompt_len {plen} + max_new_tokens "
                    f"{r.max_new_tokens} exceeds the cache depth "
                    f"(max_len={self.max_len}); decode would write past the "
                    f"cache — shorten the request or raise max_len")
            if self._paged:
                need = pages_for_span(0, plen + r.max_new_tokens,
                                      self._page_size)
                if need > self._num_pages - 1:
                    raise ValueError(
                        f"request {i}: needs {need} pages alone but the "
                        f"pool holds {self._num_pages - 1} allocatable "
                        f"pages (num_pages={self._num_pages}, page_size="
                        f"{self._page_size}) — grow the pool")

    def _note_cache(self, cache: DecodeCache) -> None:
        """Track the largest resident decode-cache footprint of this
        ``generate()`` call — the paged-vs-contiguous benchmark metric."""
        n = 0
        for leaf in jax.tree_util.tree_leaves(cache):
            n += leaf.size * leaf.dtype.itemsize
        self.cache_bytes_resident = max(self.cache_bytes_resident, n)

    # -- public API ------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        self._validate(requests)
        self.cache_bytes_resident = 0
        ctx = (set_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            if self._padded_ok:
                pending = list(requests)
                while pending:
                    batch = self._take_batch(pending)
                    self._run_continuous(batch, pending)
            else:
                # grouped fallback: equal prompt lengths per batch
                groups: Dict[int, List[Request]] = {}
                for r in requests:
                    groups.setdefault(int(r.prompt.shape[-1]), []).append(r)
                for _, rs in sorted(groups.items()):
                    for i in range(0, len(rs), self.batch_size):
                        self._run_grouped(rs[i: i + self.batch_size])
        return requests

    # -- batch formation -------------------------------------------------
    def _take_batch(self, pending: List[Request]) -> List[Request]:
        """Pop up to ``batch_size`` requests whose LEFT-PADDED runway fits:
        with pad width P = max(prompt lens), every member still needs
        ``P + max_new <= max_len`` (padding consumes cache depth). Paged:
        additionally, the members' worst-case page spans (prompt + full
        token budget, page-rounded — the reservation that keeps allocation
        infallible) must fit the pool together."""
        batch: List[Request] = []
        pad = 0
        i = 0
        while i < len(pending) and len(batch) < self.batch_size:
            r = pending[i]
            p_new = max(pad, int(r.prompt.shape[-1]))
            members = batch + [r]
            fits = all(p_new + q.max_new_tokens <= self.max_len
                       for q in members)
            if fits and self._paged:
                fits = sum(
                    pages_for_span(p_new - int(q.prompt.shape[-1]),
                                   p_new + q.max_new_tokens,
                                   self._page_size)
                    for q in members) <= self._num_pages - 1
            if fits:
                batch.append(pending.pop(i))
                pad = p_new
            else:
                i += 1
        assert batch, "a validated request always fits alone"
        return batch

    # -- continuous (left-padded) path ------------------------------------
    def _run_continuous(self, batch: List[Request],
                        pending: List[Request]) -> None:
        cfg = self.cfg
        B = self.batch_size
        PS = self._page_size
        slots = [_Slot() for _ in range(B)]
        for s, r in zip(slots, batch):
            s.activate(r)
        plens = [int(s.req.prompt.shape[-1]) if s.req is not None
                 else int(batch[0].prompt.shape[-1]) for s in slots]
        pad = max(plens)
        tokens = np.zeros((B, pad), np.int32)
        for i, s in enumerate(slots):
            prm = (s.req or batch[0]).prompt
            tokens[i, pad - plens[i]:] = prm
        start = np.asarray([pad - p for p in plens], np.int32)
        temps = np.asarray([self._temp_of(s.req) if s.req else 0.0
                            for s in slots], np.float32)
        reserved: Dict[int, int] = {}  # slot -> worst-case page span
        if self._paged:
            cache = init_paged_cache(cfg, B, self.max_len, page_size=PS,
                                     num_pages=self._num_pages,
                                     dtype=self._cache_dtype)
            self._owner = page_state_init(self._num_pages, B,
                                          self._max_pages).owner
            for i, s in enumerate(slots):
                if s.req is None:
                    continue  # empty slot: writes land in the trash page
                cache = self._palloc(cache, i, int(start[i]) // PS,
                                     (pad - 1) // PS)
                reserved[i] = pages_for_span(
                    int(start[i]), pad + s.req.max_new_tokens, PS)
        else:
            cache = init_cache(cfg, B, self.max_len, dtype=self._cache_dtype)
        self._note_cache(cache)
        nxt, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens)}, cache,
            jnp.asarray(start), jnp.asarray(temps), self._next_key())
        cur = pad

        def record(s: _Slot, t: int) -> None:
            if s.req.stop_token is not None and t == s.req.stop_token:
                s.finish()
                return
            s.tokens.append(t)
            if len(s.tokens) >= s.req.max_new_tokens:
                s.finish()

        def reclaim(i: int, s: _Slot, cache):
            """Per-slot compaction for free: the instant a slot finishes its
            pages go back to the pool (its decode writes re-route to the
            trash page through the cleared table row)."""
            if not (self._paged and s.done and i in reserved):
                return cache
            st = free_slot_pages_jit(
                PageState(cache.kv.table, self._owner),
                jnp.asarray(i, jnp.int32))
            self._owner = st.owner
            reserved.pop(i, None)
            return self._with_table(cache, st.table)

        while True:
            toks = np.array(nxt)  # copy: admission may overwrite a row
            admitted = False
            for i, s in enumerate(slots):
                if not s.done and s.req is not None:
                    record(s, int(toks[i, 0]))
                    cache = reclaim(i, s, cache)
            # early slot recycling: prefill the next request into a finished
            # slot just below the shared cursor (start masks older rows)
            if self._can_admit and pending:
                for i, s in enumerate(slots):
                    if not s.done or not pending:
                        continue
                    j = self._admittable(pending, cur, reserved)
                    if j is None:
                        continue
                    r = pending.pop(j)
                    plen = int(r.prompt.shape[-1])
                    if self._paged:
                        cache = self._palloc(cache, i, (cur - plen) // PS,
                                             (cur - 1) // PS)
                        reserved[i] = pages_for_span(
                            cur - plen, cur + r.max_new_tokens, PS)
                    tok0, cache = self._admit(r, cache, i, cur)
                    s.activate(r)
                    start[i] = cur - plen
                    temps[i] = self._temp_of(r)
                    toks[i, 0] = tok0
                    record(s, tok0)  # the admission prefill's first token
                    cache = reclaim(i, s, cache)
                    admitted = True
            if all(s.done or s.req is None for s in slots):
                break
            if admitted:
                nxt = jnp.asarray(toks)
            if cur >= self.max_len:  # defensive: budgets guarantee this
                for s in slots:      # never trips (validated runways)
                    if not s.done:
                        s.finish()
                break
            if self._paged and cur % PS == 0:
                # the shared cursor crosses into a fresh logical page: every
                # live slot gets one (reservation makes this infallible)
                act = [i for i, s in enumerate(slots) if not s.done]
                if act:
                    st, ok = alloc_step_pages_jit(
                        PageState(cache.kv.table, self._owner),
                        jnp.asarray(act, jnp.int32),
                        jnp.asarray(cur // PS, jnp.int32))
                    if not bool(ok):  # reservations make this unreachable
                        raise RuntimeError(
                            "page pool exhausted at the decode boundary — "
                            "reservation accounting broken")
                    self._owner = st.owner
                    cache = self._with_table(cache, st.table)
            nxt, cache = self._step(self.params, nxt, cache,
                                    jnp.asarray(start), jnp.asarray(temps),
                                    self._next_key())
            cur += 1

    def _admittable(self, pending: List[Request], cur: int,
                    reserved: Optional[Dict[int, int]] = None
                    ) -> Optional[int]:
        """Index of the first pending request that fits at cursor ``cur``:
        its prompt must fit below the cursor and its token budget inside the
        remaining cache depth — and, paged, its worst-case page span must
        fit next to the live slots' reservations."""
        for j, r in enumerate(pending):
            plen = int(r.prompt.shape[-1])
            if plen > cur or cur + r.max_new_tokens > self.max_len:
                continue
            if self._paged:
                need = pages_for_span(cur - plen, cur + r.max_new_tokens,
                                      self._page_size)
                if sum(reserved.values()) + need > self._num_pages - 1:
                    continue
            return j
        return None

    # -- page-pool bookkeeping (paged mode) --------------------------------
    def _with_table(self, cache: DecodeCache, table) -> DecodeCache:
        kv = cache.kv
        return DecodeCache(
            PagedKVCache(kv.k, kv.v, table, kv.length, kv.page_size),
            cache.ssm, cache.length)

    def _palloc(self, cache: DecodeCache, slot: int, lo_page: int,
                hi_page: int) -> DecodeCache:
        """Map fresh pool pages at ``slot``'s logical pages [lo, hi]."""
        logical = jnp.arange(lo_page, hi_page + 1, dtype=jnp.int32)
        st, ok = alloc_slot_pages_jit(
            PageState(cache.kv.table, self._owner),
            jnp.asarray(slot, jnp.int32), logical)
        if not bool(ok):  # reservations make this unreachable
            raise RuntimeError("page pool exhausted at prefill/admission — "
                               "reservation accounting broken")
        self._owner = st.owner
        return self._with_table(cache, st.table)

    def _admit(self, r: Request, cache, slot: int, cur: int):
        """Prefill ``r`` alone and splice its KV rows into ``slot``'s cache
        at virtual positions ``[cur - plen, cur)``; returns (first token,
        cache). Contiguous: a dynamic_update_slice into the slot's row,
        single-device only. Paged: a page-table splice into the slot's
        freshly allocated pages — under a mesh the prefill runs replicated
        over the data axes with the running batch's TP specs, the
        shard-aware admission the contiguous splice can't do."""
        plen = int(r.prompt.shape[-1])
        p_adm = min(-(-plen // _ADMIT_ALIGN) * _ADMIT_ALIGN, cur)
        fn = self._admit_fn(p_adm)
        tokens = np.zeros((1, p_adm), np.int32)
        tokens[0, p_adm - plen:] = r.prompt
        nxt, cache = fn(self.params, jnp.asarray(tokens), cache,
                        jnp.asarray(slot, jnp.int32),
                        jnp.asarray(cur - p_adm, jnp.int32),
                        jnp.asarray([p_adm - plen], jnp.int32),
                        jnp.asarray([self._temp_of(r)], jnp.float32),
                        self._next_key())
        return int(np.asarray(nxt)[0, 0]), cache

    def _admit_fn(self, p_adm: int):
        """Jitted single-request admission prefill, cached per padded
        prompt width (widths are rounded to ``_ADMIT_ALIGN`` to bound the
        number of traces). The cache write is the only layout-specific
        part: contiguous DUS splice vs page-table splice."""
        fn = self._admit_fns.get(p_adm)
        if fn is not None:
            return fn
        if self.comm_plan is not None:
            fn = self._build_admit_comm(p_adm)  # paged-only (_can_admit)
        else:
            cfg = self.cfg
            model = Model(cfg)
            paged = self._paged

            def admit(params, tokens, cache, slot, dest, start1, temp1, key):
                tmp = init_cache(cfg, 1, tokens.shape[1],
                                 dtype=self._cache_dtype)
                logits, _, tmp = model.forward(params, {"tokens": tokens},
                                               cache=tmp, start=start1)
                nxt = select_tokens(_last_logits(cfg, logits), temp1, key)
                if paged:
                    kv = paged_splice(cache.kv, slot, dest,
                                      tmp.kv.k[:, 0], tmp.kv.v[:, 0])
                else:
                    k = jax.lax.dynamic_update_slice(
                        cache.kv.k, tmp.kv.k.astype(cache.kv.k.dtype),
                        (0, slot, dest, 0, 0))
                    v = jax.lax.dynamic_update_slice(
                        cache.kv.v, tmp.kv.v.astype(cache.kv.v.dtype),
                        (0, slot, dest, 0, 0))
                    kv = KVCache(k, v, cache.kv.length, cache.kv.ring)
                return nxt, DecodeCache(kv, cache.ssm, cache.length)

            fn = jax.jit(admit, donate_argnums=(2,))
        self._admit_fns[p_adm] = fn
        return fn

    def _build_admit_comm(self, p_adm: int):
        """Admission prefill on the manual-TP (VCI stream) path: B=1
        replicates over the data axes, weights stay Megatron-sharded, the
        collectives ride lane 0's per-purpose streams, and the splice writes
        each rank's LOCAL KV heads into its local page pool shard."""
        cfg, mesh, plan = self.cfg, self.mesh, self.comm_plan
        assert mesh is not None
        tp = _mesh_tp(mesh)
        kvh = cfg.num_kv_heads * max(1, cfg.decode_kv_expand)
        kv_loc = kvh // tp if (tp > 1 and kvh % tp == 0) else kvh

        def admit(params, tokens, cache, slot, dest, start1, temp1, key):
            def inner(params, tokens, cache, slot, dest, start1, temp1, key):
                comm = plan.comm(0)
                model = Model(cfg, None, comm=comm)
                shape = (cfg.num_layers, 1, tokens.shape[1], kv_loc,
                         cfg.head_dim)
                dt = cache.kv.k.dtype
                tmp = DecodeCache(
                    KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                            jnp.zeros((), jnp.int32), False),
                    None, jnp.zeros((), jnp.int32))
                logits, _, tmp = model.forward(params, {"tokens": tokens},
                                               cache=tmp, start=start1)
                logits = comm.drain(logits)
                nxt = select_tokens(_last_logits(cfg, logits), temp1, key)
                kv = paged_splice(cache.kv, slot, dest,
                                  tmp.kv.k[:, 0], tmp.kv.v[:, 0])
                return nxt, DecodeCache(kv, None, cache.length)

            cspec = serve_cache_specs(cache, tp, 1)
            f = shard_map(
                inner, mesh=mesh,
                in_specs=(serve_param_specs(cfg, params, tp),
                          P(None, None), cspec, P(), P(), P(), P(), P()),
                out_specs=(P(None, None), cspec),
                check_vma=False, axis_names=set(mesh.axis_names))
            return f(params, tokens, cache, slot, dest, start1, temp1, key)

        return jax.jit(admit, donate_argnums=(2,))

    # -- grouped (equal prompt length) fallback ---------------------------
    def _run_grouped(self, reqs: List[Request]) -> None:
        cfg = self.cfg
        b = len(reqs)
        prompts = np.stack([r.prompt for r in reqs])
        cache = init_cache(cfg, b, self.max_len, dtype=self._cache_dtype)
        self._note_cache(cache)
        temps = np.asarray([self._temp_of(r) for r in reqs], np.float32)
        # comm-mode step functions take concrete (all-zero) start offsets;
        # the plain path keeps None (SSM/audio reject per-row offsets).
        start = (None if self.comm_plan is None
                 else jnp.zeros((b,), jnp.int32))
        nxt, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, cache, start,
            jnp.asarray(temps), self._next_key())
        text = cfg.modality == "text"
        gen = [np.asarray(nxt)]
        stopped = [False] * b

        def update_stops():
            if not text:
                return
            for i, r in enumerate(reqs):
                if r.stop_token is not None and \
                        int(gen[-1][i, 0]) == r.stop_token:
                    stopped[i] = True

        update_stops()
        while any(not stopped[i] and len(gen) < r.max_new_tokens
                  for i, r in enumerate(reqs)):
            nxt, cache = self._step(self.params, nxt, cache, start,
                                    jnp.asarray(temps), self._next_key())
            gen.append(np.asarray(nxt))
            update_stops()
        toks = np.concatenate(gen, axis=-1)  # (B,steps) or (B,K,steps)
        for i, r in enumerate(reqs):
            seq = toks[i][..., : r.max_new_tokens]
            if text and r.stop_token is not None:
                hits = np.nonzero(seq == r.stop_token)[0]
                if hits.size:
                    seq = seq[: int(hits[0])]
            r.generated = seq
