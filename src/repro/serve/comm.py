"""Serve-path communication streams — VCIs for decode/prefill collectives.

The gradient path (``core/bucketing.py``) maps each gradient bucket onto a
CommContext/VCI so XLA may overlap the B reductions. The serve path has the
same shape of user-exposed parallelism, just with different *purposes*: every
decode step issues TP partial-sum all-reduces (attention ``wo`` and FFN
``w_down`` row-parallel matmuls), MoE dispatch/combine resharding, and the
vocab-parallel sampling gather. Running them on XLA's default ordering is the
"one global stream" anti-pattern of the paper's Fig. 4; :class:`ServeCommPlan`
is the serve-side mirror of :class:`~repro.core.bucketing.CommPlan` — a
host-persistent object holding ONE ``CommWorld`` plus per-lane/per-purpose
``CommContext``s, minting a fresh trace-local ``CommRuntime`` per trace.

Purposes (one context — hence one VCI stream — per purpose, per lane):

* ``tp_attn``  — attention output-projection partial sums (row-parallel wo);
* ``tp_mlp``   — FFN down-projection partial sums (row-parallel w_down);
* ``moe``      — MoE expert dispatch/combine resharding (expert-parallel
                 all-gather of expert outputs, or the ff-TP partial-sum
                 all-reduce when experts don't divide the axis);
* ``sample``   — vocab-parallel embedding/logits collectives feeding the
                 sampler (the KV-cache/sampling stream).

A *lane* is one concurrently-decoding batch: ``ServeCommPlan(lanes=G)``
pre-creates G disjoint context sets so G decode batches traced into one
program ride G×4 independent streams. With ``num_vcis`` below the live
context count the pool falls back exactly as §4.2 describes — contexts
collide on VCI 0, their ordering tokens chain, and the lanes serialize: the
serve-side reproduction of the Fig. 17 mapping mismatch, measured by
``benchmarks/serve_streams.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.collectives import CommRuntime
from repro.core.comm import CommContext, CommWorld

PURPOSES = ("tp_attn", "tp_mlp", "moe", "sample")

TP_AXIS = "model"


@dataclass
class ServeComm:
    """Trace-local view threaded through the model's decode/prefill code.

    Binds one lane's contexts to a (possibly shared) :class:`CommRuntime`:
    sharing one runtime across lanes is what lets contexts that COLLIDED in
    the VCI pool serialize through the shared per-VCI ordering token.
    """

    rt: CommRuntime
    contexts: Dict[str, CommContext]
    axis: str = TP_AXIS

    @property
    def size(self) -> int:
        from repro.compat import axis_size
        return axis_size(self.axis)

    def rank(self):
        return lax.axis_index(self.axis)

    def psum(self, x, purpose: str):
        """Partial-sum all-reduce on the purpose's VCI stream."""
        return self.rt.all_reduce(x, self.contexts[purpose], axis=self.axis)

    def all_gather(self, x, purpose: str, gather_axis: int):
        return self.rt.all_gather(x, self.contexts[purpose], axis=self.axis,
                                  gather_axis=gather_axis, tiled=True)

    def all_to_all(self, x, purpose: str, *, split_axis: int,
                   concat_axis: int):
        return self.rt.all_to_all(x, self.contexts[purpose], axis=self.axis,
                                  split_axis=split_axis,
                                  concat_axis=concat_axis)

    def drain(self, x):
        """Order ``x`` after every stream (step-end global progress)."""
        return self.rt.barrier(x)


class ServeCommPlan:
    """Host-persistent serve comm plan (the serve mirror of ``CommPlan``).

    Built once per engine/benchmark; every trace mints a fresh runtime via
    :meth:`runtime` (ordering tokens are trace-local) while the world, the
    VCI pool and the contexts persist — so pool statistics accumulate across
    traces and the VCI mapping is decided exactly once, at creation time,
    like ``MPI_Comm_create``.
    """

    def __init__(self, *, num_vcis: int = 8, vci_policy: str = "fcfs",
                 lanes: int = 1, progress: str = "hybrid",
                 join_every: int = 8, token_impl: str = "barrier"):
        if lanes < 1:
            raise ValueError(f"need at least one lane, got {lanes}")
        self.lanes = lanes
        self.progress = progress
        self.join_every = join_every
        self.token_impl = token_impl
        self.world = CommWorld(num_vcis=num_vcis, policy=vci_policy)
        self.contexts: Dict[Tuple[int, str], CommContext] = {}
        for lane in range(lanes):
            for purpose in PURPOSES:
                hint = "dedicated" if vci_policy == "hinted" else None
                self.contexts[(lane, purpose)] = self.world.create(
                    f"lane{lane}.{purpose}", kind="p2p", hint=hint)

    def runtime(self) -> CommRuntime:
        """A fresh per-trace runtime bound to the persistent world."""
        return CommRuntime(self.world, progress=self.progress,
                           join_every=self.join_every,
                           token_impl=self.token_impl)

    def comm(self, lane: int = 0, *, rt: Optional[CommRuntime] = None,
             axis: str = TP_AXIS) -> ServeComm:
        """The lane's trace-local comm view. Pass one shared ``rt`` when
        tracing several lanes into one program (collision semantics)."""
        if not 0 <= lane < self.lanes:
            raise ValueError(f"lane {lane} outside [0, {self.lanes})")
        ctxs = {p: self.contexts[(lane, p)] for p in PURPOSES}
        return ServeComm(rt or self.runtime(), ctxs, axis=axis)

    @property
    def stats(self):
        return self.world.stats

    def vci_map(self) -> Dict[str, int]:
        """{context name: vci index} — the realized mapping, for reporting."""
        return {c.name: c.vci.index for c in self.contexts.values()}


# ---------------------------------------------------------------------------
# manual-TP parameter/cache specs for the comm-mode decode step
# ---------------------------------------------------------------------------

def serve_tp_validate(cfg: ModelConfig, tp: int) -> None:
    """The divisibility contract of the manual-TP serve path."""
    if tp <= 1:
        return
    problems = []
    if cfg.family not in ("dense", "moe"):
        problems.append(f"family {cfg.family!r} (attention archs only)")
    if cfg.modality != "text":
        problems.append(f"modality {cfg.modality!r}")
    if cfg.num_heads % tp:
        problems.append(f"num_heads {cfg.num_heads} % tp")
    if cfg.num_kv_heads % tp:
        problems.append(f"num_kv_heads {cfg.num_kv_heads} % tp")
    if cfg.d_ff % tp:
        problems.append(f"d_ff {cfg.d_ff} % tp")
    if cfg.vocab_size % tp:
        problems.append(f"vocab_size {cfg.vocab_size} % tp")
    if cfg.decode_kv_expand != 1:
        problems.append("decode_kv_expand != 1")
    if cfg.moe is not None and (cfg.moe.num_experts % tp
                                and cfg.d_ff % tp):
        problems.append(f"num_experts {cfg.moe.num_experts} % tp")
    if problems:
        raise ValueError(
            f"arch {cfg.name!r} cannot run the manual-TP serve path at "
            f"tp={tp}: " + "; ".join(problems))


def serve_param_specs(cfg: ModelConfig, params, tp: int, *,
                      axis: str = TP_AXIS):
    """PartitionSpec tree for the comm-mode (manual TP) decode step.

    Megatron layout: wq/wk/wv/w_gate/w_up column-parallel, wo/w_down
    row-parallel, biases follow their matmul (b_down/bo replicated — added
    AFTER the partial-sum all-reduce). Embedding and lm_head are
    vocab-parallel, feeding the ``sample`` stream's psum/all-gather. MoE
    expert tables are expert-parallel over the TP axis when the expert count
    divides, else ff-TP within every expert. Norm scales and the router
    replicate.
    """
    col = frozenset({"wq", "wk", "wv", "w_gate", "w_up"})
    row = frozenset({"wo", "w_down"})
    col_bias = frozenset({"bq", "bk", "bv", "b_up"})
    moe_expert_parallel = (cfg.moe is not None
                           and cfg.moe.num_experts % tp == 0)

    def assign(path, leaf):
        keys = tuple(str(getattr(k, "key", getattr(k, "name", k)))
                     for k in path)
        name, parent = keys[-1], (keys[-2] if len(keys) >= 2 else "")
        nd = leaf.ndim
        spec = [None] * nd
        if tp == 1 or nd == 0:
            return P(*spec)
        if parent == "embed" and nd >= 2:
            spec[nd - 2] = axis            # (V, d): vocab-parallel rows
        elif parent == "lm_head":
            spec[nd - 1] = axis            # (d, V): vocab-parallel columns
        elif parent == "moe" and name in ("w_gate", "w_up", "w_down"):
            if moe_expert_parallel:
                spec[nd - 3] = axis        # (E, a, b): expert-parallel
            else:
                ff_dim = nd - 1 if name in ("w_gate", "w_up") else nd - 2
                spec[ff_dim] = axis        # ff-TP within every expert
        elif name == "router":
            pass
        elif name in col and nd >= 2:
            spec[nd - 1] = axis
        elif name in row and nd >= 2:
            spec[nd - 2] = axis
        elif name in col_bias:
            spec[nd - 1] = axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, params)


def serve_cache_specs(cache, tp: int, batch_shards: int, *,
                      axis: str = TP_AXIS, batch_axis="data"):
    """Spec tree for a DecodeCache: KV heads over the TP axis, batch over
    ``batch_axis`` (a mesh axis name or tuple — pass the SAME entry the
    token spec uses); scalars (cursor lengths) replicate.

    Paged caches (:class:`repro.models.attention.PagedKVCache`): the page
    pool is a SHARED resource — any slot may hold any page — so it cannot
    shard over the batch axes; pools replicate over data and shard only
    their KV heads over the TP axis, and the page table / cursor replicate.
    (That is exactly the paper's argument inverted: the pool is the one
    deliberately-shared resource, and the per-purpose VCI streams are what
    keep the lanes from serializing on it.)
    """
    from repro.models.attention import PagedKVCache

    def assign(leaf):
        if getattr(leaf, "ndim", 0) == 5:   # (L, B, S, KV, hd) stacked cache
            b_ax = batch_axis if (batch_shards > 1
                                  and leaf.shape[1] % batch_shards == 0) else None
            kv_ax = axis if (tp > 1 and leaf.shape[3] % tp == 0) else None
            return P(None, b_ax, None, kv_ax, None)
        return P()

    kv = getattr(cache, "kv", None)
    if isinstance(kv, PagedKVCache):
        kv_ax = axis if (tp > 1 and kv.k.shape[3] % tp == 0) else None
        pool = P(None, None, None, kv_ax, None)   # (L, NP, PS, KV, hd)
        kv_spec = PagedKVCache(pool, pool, P(), P(), kv.page_size)
        rest = jax.tree_util.tree_map(assign, cache.ssm)
        return type(cache)(kv_spec, rest, P())
    return jax.tree_util.tree_map(assign, cache)
