"""Train-step builders.

Two gradient-communication modes:

* ``comm="gspmd"`` (production default, used by the dry-run): parameters are
  FSDP(data/pod) x TP(model) sharded; XLA inserts the gradient
  reduce-scatters/all-gathers from the sharding constraints.

* ``comm="vci"`` (the paper's mode): the step runs under ``shard_map`` with
  the data axes MANUAL and the model axis auto (GSPMD). Parameters are
  replicated over data (DDP); gradients are explicitly partitioned into
  buckets, each bucket assigned a CommContext -> VCI, and reduced on
  independent streams by :func:`repro.core.bucketing.reduce_gradients`.
  ``progress`` / ``num_streams`` / ``vci_policy`` / ``token_impl`` expose the
  paper's entire design space (Global vs FG vs per-VCI, Fig. 5-8 ablations).

  Fast-path knobs (this repo's §4.3 per-VCI-request-cache analogue; see the
  knob matrix in ``repro.core.bucketing``):

  * ``persistent_plan`` — cache the BucketPlan/CommWorld/contexts/pack
    tables across steps and retraces (True; False = seed per-step rebuild);
  * ``pack="xla"|"pallas"``   — concat-chain vs arena + fused tile-gather
    pack/unpack kernels (``repro.kernels.bucket_pack``);
  * ``reduction="all_reduce"|"reduce_scatter"`` — full all-reduce vs
    per-bucket reduce_scatter + all_gather (half the wire bytes for DDP).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import get_comm_plan, reduce_gradients
from repro.dist.sharding import Sharder, batch_axes
from repro.models.transformer import Model, init_params
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.train.losses import total_loss
from repro.compat import shard_map


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def train_state_init(cfg: ModelConfig, key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    opt = adamw_init(params, moment_dtype=jnp.dtype(cfg.optimizer_dtype))
    return TrainState(params, opt, jnp.zeros((), jnp.int32))


def _loss_fn(model: Model, cfg: ModelConfig, params, batch):
    logits, aux, _ = model.forward(params, batch)
    loss, metrics = total_loss(cfg, logits, batch["labels"], aux)
    return loss, metrics


def make_train_step(
    cfg: ModelConfig,
    *,
    mesh: Optional[Mesh] = None,
    lr_fn: Optional[Callable] = None,
    comm: str = "gspmd",
    accum_steps: int = 1,
    # --- vci-mode knobs (paper §4/§5) ---
    num_streams: int = 8,
    num_vcis: int = 8,
    vci_policy: str = "fcfs",
    progress: str = "hybrid",
    join_every: int = 8,
    token_impl: str = "barrier",
    staging: str = "per_vci",
    bucket_align: int = 8 * 128,
    # --- fast-path knobs (persistent plans + fused pack, see bucketing) ---
    pack: str = "xla",
    reduction: str = "all_reduce",
    persistent_plan: bool = True,
    max_grad_norm: Optional[float] = 1.0,
) -> Callable[[TrainState, Any], tuple]:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    The returned function is NOT jitted; callers jit with the appropriate
    in/out shardings (launch/train.py) or call it inside tests directly.
    """
    if lr_fn is None:
        lr_fn = lambda step: 3e-4
    shard = Sharder(mesh, cfg) if (mesh is not None and comm == "gspmd") else (
        Sharder(None, cfg))
    model = Model(cfg, shard if mesh is not None and comm == "gspmd" else None)

    def grads_and_metrics(params, batch):
        if accum_steps == 1:
            (_, metrics), grads = jax.value_and_grad(
                functools.partial(_loss_fn, model, cfg), has_aux=True)(
                    params, batch)
            return grads, metrics
        # microbatch accumulation: split the batch dim, scan, mean grads
        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        def body(carry, microbatch):
            acc_g, acc_m = carry
            (_, metrics), grads = jax.value_and_grad(
                functools.partial(_loss_fn, model, cfg), has_aux=True)(
                    params, microbatch)
            acc_g = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                acc_g, grads)
            acc_m = jax.tree_util.tree_map(
                lambda a, m: a + m / accum_steps, acc_m, metrics)
            return (acc_g, acc_m), None

        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        _, m0 = jax.eval_shape(
            functools.partial(_loss_fn, model, cfg), params,
            jax.tree_util.tree_map(lambda x: x[0], mb))
        zero_m = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape), m0[1] if isinstance(m0, tuple) else m0)
        (grads, metrics), _ = jax.lax.scan(body, (zero_g, zero_m), mb)
        grads = jax.tree_util.tree_map(
            lambda g, p: g.astype(p.dtype), grads, params)
        return grads, metrics

    def apply_update(state: TrainState, grads, metrics):
        lr = lr_fn(state.step)
        new_p, new_opt, om = adamw_update(
            grads, state.opt, state.params, lr=jnp.asarray(lr, jnp.float32),
            max_grad_norm=max_grad_norm)
        metrics = dict(metrics) | om | {"lr": jnp.asarray(lr, jnp.float32)}
        return TrainState(new_p, new_opt, state.step + 1), metrics

    if comm == "gspmd":
        def train_step(state: TrainState, batch):
            grads, metrics = grads_and_metrics(state.params, batch)
            return apply_update(state, grads, metrics)
        return train_step

    if comm != "vci":
        raise ValueError(f"unknown comm mode {comm!r}")

    # ---------------- vci mode -------------------------------------------
    assert mesh is not None, "vci mode needs a mesh"
    dp = batch_axes(mesh)

    def inner_step(state: TrainState, batch):
        grads, metrics = grads_and_metrics(state.params, batch)
        # Persistent plan: BucketPlan + CommWorld + contexts + pack tables
        # are cached on (treedef, shapes, knobs) — rebuilt per call only in
        # the per-step ablation mode. The CommRuntime (ordering tokens) is
        # trace-local and minted fresh either way.
        cp = get_comm_plan(grads, num_streams=num_streams, align=bucket_align,
                           pack=pack, num_vcis=num_vcis,
                           vci_policy=vci_policy, progress=progress,
                           join_every=join_every, token_impl=token_impl,
                           persistent=persistent_plan)
        grads = reduce_gradients(cp.runtime(), grads, cp, axis=dp, mean=True,
                                 staging=staging, pack=pack,
                                 reduction=reduction)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp), metrics)
        return apply_update(state, grads, metrics)

    METRIC_KEYS = ("ce", "tokens", "load_balance", "router_z", "loss",
                   "grad_norm", "lr")

    def train_step(state: TrainState, batch):
        in_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            jax.tree_util.tree_map(lambda _: P(dp), batch),
        )
        out_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            {k: P() for k in METRIC_KEYS},
        )
        f = shard_map(inner_step, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False,
                      axis_names=set(dp))
        return f(state, batch)

    return train_step
