"""Train-step builders.

Two gradient-communication modes:

* ``comm="gspmd"`` (production default, used by the dry-run): parameters are
  FSDP(data/pod) x TP(model) sharded; XLA inserts the gradient
  reduce-scatters/all-gathers from the sharding constraints.

* ``comm="vci"`` (the paper's mode): the step runs under ``shard_map`` with
  the data axes MANUAL and the model axis auto (GSPMD). Parameters are
  replicated over data (DDP); gradients are explicitly partitioned into
  buckets, each bucket assigned a CommContext -> VCI, and reduced on
  independent streams by :func:`repro.core.bucketing.reduce_gradients`.
  ``progress`` / ``num_streams`` / ``vci_policy`` / ``token_impl`` expose the
  paper's entire design space (Global vs FG vs per-VCI, Fig. 5-8 ablations).

  Fast-path knobs (this repo's §4.3 per-VCI-request-cache analogue; see the
  knob matrix in ``repro.core.bucketing``):

  * ``persistent_plan`` — cache the BucketPlan/CommWorld/contexts/pack
    tables across steps and retraces (True; False = seed per-step rebuild);
  * ``pack="xla"|"pallas"``   — concat-chain vs arena + fused tile-gather
    pack/unpack kernels (``repro.kernels.bucket_pack``);
  * ``reduction="all_reduce"|"reduce_scatter"`` — full all-reduce vs
    per-bucket reduce_scatter + all_gather (half the wire bytes for DDP).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import TILE, get_comm_plan, reduce_gradients
from repro.core.bucketing import (ShardLayout, all_gather_shards,
                                  overlap_boundaries, plan_buckets)
from repro.dist.sharding import Sharder, batch_axes, dp_entry, zero1_opt_specs
from repro.models.transformer import Model, init_params
from repro.optim.adamw import (adamw_init, adamw_update,
                               bucket_decay_masks, sharded_adamw_init,
                               sharded_adamw_update)
from repro.train.losses import total_loss
from repro.compat import shard_map


class TrainState(NamedTuple):
    params: Any
    opt: Any                     # AdamWState | ShardedAdamWState (zero1)
    step: jax.Array


def _zero1_plan(params_or_grads, *, num_streams: int, align: int, pack: str,
                schedule: str = "post"):
    """The bucket plan the zero1 path uses — MUST match what the step's
    ``get_comm_plan`` builds, so state init and update agree on layout.
    ``schedule="overlap"`` plans use-order-contiguous buckets (the
    bucket-ready layout), so the flat state layout differs from ``"post"``
    and state must be initialized with the matching schedule."""
    slot_align = align if pack == "pallas" else None
    return plan_buckets(params_or_grads, num_streams, align=align,
                        slot_align=slot_align,
                        partition="contig" if schedule == "overlap"
                        else "size")


def train_state_init(cfg: ModelConfig, key: jax.Array, *,
                     optimizer: str = "replicated",
                     mesh=None, num_streams: int = 8,
                     bucket_align: int = TILE,
                     pack: str = "xla",
                     schedule: str = "post") -> TrainState:
    """Fresh params + optimizer state.

    ``optimizer="zero1"`` builds the ZeRO-1 flat-bucket state
    (:func:`sharded_adamw_init`): pass the SAME ``mesh`` / ``num_streams`` /
    ``bucket_align`` / ``pack`` / ``schedule`` the matching
    ``make_train_step`` gets, since the bucket plan (and therefore every
    buffer's layout) derives from them.
    """
    params = init_params(cfg, key)
    if optimizer == "replicated":
        opt = adamw_init(params, moment_dtype=jnp.dtype(cfg.optimizer_dtype))
    elif optimizer == "zero1":
        if mesh is None:
            raise ValueError("optimizer='zero1' needs a mesh (the data axes "
                             "define the shard layout)")
        plan = _zero1_plan(params, num_streams=num_streams,
                           align=bucket_align, pack=pack, schedule=schedule)
        n = 1
        for a in batch_axes(mesh):
            n *= dict(mesh.shape)[a]
        ShardLayout(plan, n)  # validate divisibility up front
        opt = sharded_adamw_init(params, plan,
                                 moment_dtype=jnp.dtype(cfg.optimizer_dtype))
    else:
        raise ValueError(f"unknown optimizer {optimizer!r}")
    return TrainState(params, opt, jnp.zeros((), jnp.int32))


def _loss_fn(model: Model, cfg: ModelConfig, params, batch):
    logits, aux, _ = model.forward(params, batch)
    loss, metrics = total_loss(cfg, logits, batch["labels"], aux)
    return loss, metrics


def make_train_step(
    cfg: ModelConfig,
    *,
    mesh: Optional[Mesh] = None,
    lr_fn: Optional[Callable] = None,
    comm: str = "gspmd",
    accum_steps: int = 1,
    # --- vci-mode knobs (paper §4/§5) ---
    num_streams: int = 8,
    num_vcis: int = 8,
    vci_policy: str = "fcfs",
    progress: str = "hybrid",
    join_every: int = 8,
    token_impl: str = "barrier",
    staging: str = "per_vci",
    bucket_align: int = 8 * 128,
    # --- fast-path knobs (persistent plans + fused pack, see bucketing) ---
    pack: str = "xla",
    reduction: str = "all_reduce",
    persistent_plan: bool = True,
    max_grad_norm: Optional[float] = 1.0,
    # --- optimizer layout (ZeRO-1) ---
    optimizer: str = "replicated",
    zero1_wire_dtype: Optional[str] = None,
    # --- comm schedule (bucket-ready overlap) ---
    schedule: str = "post",
) -> Callable[[TrainState, Any], tuple]:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    The returned function is NOT jitted; callers jit with the appropriate
    in/out shardings (launch/train.py) or call it inside tests directly.

    ``optimizer`` selects the optimizer layout (vci mode only):

    * ``"replicated"`` — every rank reduces the full gradient tree and
      applies the full AdamW update (DDP).
    * ``"zero1"`` — ZeRO-1: per-bucket ``reduce_scatter`` hands each rank
      only its :class:`ShardLayout` shard, :func:`sharded_adamw_update`
      updates m/v and the fp32 master copy for that shard alone, and the
      *updated params* are all-gathered once per bucket on the SAME
      CommContext/VCI the reduce used. Gradient wire bytes are halved
      (scatter only, no gradient gather) and optimizer memory drops 1/N.
      State must come from ``train_state_init(optimizer="zero1")`` with
      matching mesh/num_streams/bucket_align/pack/schedule.
      ``zero1_wire_dtype`` (e.g. ``"bfloat16"``) sets the payload dtype of
      BOTH the gradient scatter and the param gather — the mixed-precision
      deployment recipe (fp32 master shards absorb the wire rounding);
      ``None`` keeps f32 wire, which matches the replicated path to fp32
      tolerance.

    ``schedule`` selects WHEN gradient reduction happens (vci mode only):

    * ``"post"`` — the classic post-pass: the full backward finishes, then
      every bucket is packed and reduced.
    * ``"overlap"`` — bucket-ready overlap
      (:func:`repro.core.bucketing.overlap_boundaries`): each bucket's
      reduce is issued on its VCI stream *inside the backward*, the moment
      its cotangents exist, so communication runs concurrently with the
      remaining backward compute (same wire bytes, shorter critical path).
      With microbatch accumulation only the LAST microbatch's backward
      carries the boundaries — earlier microbatches accumulate locally and
      their sum rides into the boundary as a carry, so reduces are issued
      once per step, not per microbatch. With ``optimizer="zero1"`` the
      per-bucket sharded-AdamW update and updated-param all_gather are
      additionally issued in backward ready order
      (``CommPlan.ready_order``), pipelining the gather latency behind
      later buckets' reduces.
    """
    if optimizer not in ("replicated", "zero1"):
        raise ValueError(f"unknown optimizer {optimizer!r}")
    if optimizer == "zero1" and comm != "vci":
        raise ValueError("optimizer='zero1' requires comm='vci' (the "
                         "bucketed reduce_scatter path)")
    if schedule not in ("post", "overlap"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if schedule == "overlap" and comm != "vci":
        raise ValueError("schedule='overlap' requires comm='vci' (the "
                         "bucketed reduction path)")
    if schedule == "overlap" and staging != "per_vci":
        raise ValueError("schedule='overlap' requires staging='per_vci': "
                         "shared staging threads one buffer through every "
                         "bucket, which re-serializes the backward-issued "
                         "reduces it exists to overlap")
    if lr_fn is None:
        lr_fn = lambda step: 3e-4
    shard = Sharder(mesh, cfg) if (mesh is not None and comm == "gspmd") else (
        Sharder(None, cfg))
    model = Model(cfg, shard if mesh is not None and comm == "gspmd" else None)

    def _mb_split(batch):
        """Split the batch dim into ``accum_steps`` leading microbatches."""
        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])
        return jax.tree_util.tree_map(split, batch)

    def _mb_zero_acc(params, mb):
        """(zero f32 grad acc, zero metric acc) for the scan carry."""
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        _, m0 = jax.eval_shape(
            functools.partial(_loss_fn, model, cfg), params,
            jax.tree_util.tree_map(lambda x: x[0], mb))
        zero_m = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape),
            m0[1] if isinstance(m0, tuple) else m0)
        return zero_g, zero_m

    def _mb_body(params):
        def body(carry, microbatch):
            acc_g, acc_m = carry
            (_, metrics), grads = jax.value_and_grad(
                functools.partial(_loss_fn, model, cfg), has_aux=True)(
                    params, microbatch)
            acc_g = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                acc_g, grads)
            acc_m = jax.tree_util.tree_map(
                lambda a, m: a + m / accum_steps, acc_m, metrics)
            return (acc_g, acc_m), None
        return body

    def grads_and_metrics(params, batch):
        if accum_steps == 1:
            (_, metrics), grads = jax.value_and_grad(
                functools.partial(_loss_fn, model, cfg), has_aux=True)(
                    params, batch)
            return grads, metrics
        # microbatch accumulation: split the batch dim, scan, mean grads
        mb = _mb_split(batch)
        (grads, metrics), _ = jax.lax.scan(
            _mb_body(params), _mb_zero_acc(params, mb), mb)
        grads = jax.tree_util.tree_map(
            lambda g, p: g.astype(p.dtype), grads, params)
        return grads, metrics

    def overlap_grads_and_metrics(params, batch, loss_with_boundaries):
        """Backward with bucket boundaries: ``loss_with_boundaries(params,
        microbatch, carry) -> (metrics, grads_or_shards)`` must wrap params
        via :func:`overlap_boundaries`. Only the LAST microbatch runs with
        the boundaries (triggering the reduces); earlier microbatches
        accumulate locally and ride in as the carry."""
        if accum_steps == 1:
            return loss_with_boundaries(params, batch, None)
        mb = _mb_split(batch)
        prefix = jax.tree_util.tree_map(lambda x: x[:accum_steps - 1], mb)
        last = jax.tree_util.tree_map(lambda x: x[accum_steps - 1], mb)
        (acc_g, acc_m), _ = jax.lax.scan(
            _mb_body(params), _mb_zero_acc(params, mb), prefix)
        carry = jax.lax.stop_gradient(acc_g)
        metrics_last, out = loss_with_boundaries(params, last, carry)
        metrics = jax.tree_util.tree_map(
            lambda a, m: a + m / accum_steps, acc_m, metrics_last)
        return metrics, out

    def apply_update(state: TrainState, grads, metrics):
        lr = lr_fn(state.step)
        new_p, new_opt, om = adamw_update(
            grads, state.opt, state.params, lr=jnp.asarray(lr, jnp.float32),
            max_grad_norm=max_grad_norm)
        metrics = dict(metrics) | om | {"lr": jnp.asarray(lr, jnp.float32)}
        return TrainState(new_p, new_opt, state.step + 1), metrics

    if comm == "gspmd":
        def train_step(state: TrainState, batch):
            grads, metrics = grads_and_metrics(state.params, batch)
            return apply_update(state, grads, metrics)
        return train_step

    if comm != "vci":
        raise ValueError(f"unknown comm mode {comm!r}")

    # ---------------- vci mode -------------------------------------------
    assert mesh is not None, "vci mode needs a mesh"
    dp = batch_axes(mesh)
    n_data = 1
    for a in dp:
        n_data *= dict(mesh.shape)[a]
    wire = jnp.dtype(zero1_wire_dtype) if zero1_wire_dtype else jnp.float32

    def _comm_plan(grads):
        # Persistent plan: BucketPlan + CommWorld + contexts + pack tables
        # are cached on (treedef, shapes, knobs) — rebuilt per call only in
        # the per-step ablation mode. The CommRuntime (ordering tokens) is
        # trace-local and minted fresh either way.
        return get_comm_plan(grads, num_streams=num_streams,
                             align=bucket_align, pack=pack, num_vcis=num_vcis,
                             vci_policy=vci_policy, progress=progress,
                             join_every=join_every, token_impl=token_impl,
                             schedule=schedule, persistent=persistent_plan)

    def inner_step(state: TrainState, batch):
        grads, metrics = grads_and_metrics(state.params, batch)
        cp = _comm_plan(grads)
        grads = reduce_gradients(cp.runtime(), grads, cp, axis=dp, mean=True,
                                 staging=staging, pack=pack,
                                 reduction=reduction)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp), metrics)
        return apply_update(state, grads, metrics)

    def inner_step_overlap(state: TrainState, batch):
        # The reduces live INSIDE the backward: each bucket's custom_vjp
        # boundary issues its reduce on its VCI stream as soon as that
        # bucket's cotangents exist, so value_and_grad returns the
        # already-reduced mean gradients and there is no post-pass.
        cp = _comm_plan(state.params)

        def run_last(params, microbatch, carry):
            def loss_w(p, b):
                wp = overlap_boundaries(cp, p, axis=dp, carry=carry,
                                        accum_steps=accum_steps, mean=True,
                                        pack=pack, reduction=reduction)
                return _loss_fn(model, cfg, wp, b)
            (_, metrics), grads = jax.value_and_grad(
                loss_w, has_aux=True)(params, microbatch)
            return metrics, grads

        metrics, grads = overlap_grads_and_metrics(
            state.params, batch, run_last)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp), metrics)
        return apply_update(state, grads, metrics)

    def inner_step_zero1(state: TrainState, batch, mask_shards):
        grads, metrics = grads_and_metrics(state.params, batch)
        cp = _comm_plan(grads)
        rt = cp.runtime()
        # 1) scatter: each rank receives (and owns) 1/N of every bucket.
        shards, layout = reduce_gradients(
            rt, grads, cp, axis=dp, mean=True, staging=staging, pack=pack,
            reduction="reduce_scatter", output="shards", reduce_dtype=wire)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp), metrics)
        # 2) local AdamW on the owned shards (norm partials psum'd on the
        # first bucket's context). mask_shards arrived pre-sliced to this
        # rank's window by the P(data) in_spec.
        lr = lr_fn(state.step)
        new_shards, new_opt, om = sharded_adamw_update(
            shards, state.opt, lr=jnp.asarray(lr, jnp.float32),
            layout=layout, decay_masks=mask_shards,
            psum=lambda s: rt.all_reduce(s, cp.contexts[0], axis=dp),
            max_grad_norm=max_grad_norm)
        # 3) gather the UPDATED PARAMS per bucket on the reduce's VCI.
        new_params = all_gather_shards(rt, new_shards, cp, axis=dp,
                                       wire_dtype=wire)
        metrics = dict(metrics) | om | {"lr": jnp.asarray(lr, jnp.float32)}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    def inner_step_zero1_overlap(state: TrainState, batch, mask_shards):
        # ZeRO-1 overlap: the backward's bucket boundaries reduce_scatter
        # each bucket the moment its cotangents exist; the shards leave the
        # backward as the taps' gradients (cotangent shapes must match
        # their primals, so the 1/N shards ride a zero-initialized side
        # input instead of the params). The sharded-AdamW update and the
        # updated-param all_gather are then issued in backward READY order.
        # NOTE: with the default global-norm clip, every update depends on
        # the clip scale and therefore on the LAST scatter — the win is the
        # scatters overlapping the backward; gathers pipeline ahead of
        # later gathers only, or fully (behind still-running reduces) when
        # max_grad_norm=None removes the clip barrier.
        cp = _comm_plan(state.params)
        rt = cp.runtime()
        layout = ShardLayout(cp.plan, n_data)
        taps = tuple(jnp.zeros((s,), jnp.float32) for s in layout.shard_sizes)

        def run_last(params, microbatch, carry):
            def loss_w(p, t, b):
                wp = overlap_boundaries(cp, p, axis=dp, taps=t, carry=carry,
                                        accum_steps=accum_steps, mean=True,
                                        pack=pack, reduce_dtype=wire)
                return _loss_fn(model, cfg, wp, b)
            (_, metrics), (_, shards) = jax.value_and_grad(
                loss_w, argnums=(0, 1), has_aux=True)(
                    params, taps, microbatch)
            return metrics, shards

        metrics, shards = overlap_grads_and_metrics(
            state.params, batch, run_last)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp), metrics)
        lr = lr_fn(state.step)
        new_shards, new_opt, om = sharded_adamw_update(
            list(shards), state.opt, lr=jnp.asarray(lr, jnp.float32),
            layout=layout, decay_masks=mask_shards,
            psum=lambda s: rt.all_reduce(s, cp.contexts[0], axis=dp),
            max_grad_norm=max_grad_norm, bucket_order=cp.ready_order)
        new_params = all_gather_shards(rt, new_shards, cp, axis=dp,
                                       wire_dtype=wire,
                                       order=cp.ready_order)
        metrics = dict(metrics) | om | {"lr": jnp.asarray(lr, jnp.float32)}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    METRIC_KEYS = ("ce", "tokens", "load_balance", "router_z", "loss",
                   "grad_norm", "lr")

    def train_step(state: TrainState, batch):
        batch_spec = jax.tree_util.tree_map(lambda _: P(dp), batch)
        metric_specs = {k: P() for k in METRIC_KEYS}
        if optimizer == "zero1":
            # flat m/v/master buffers live SHARDED on the data axes; params
            # and the step count replicate (dist.sharding.zero1_opt_specs).
            state_spec = TrainState(
                params=jax.tree_util.tree_map(lambda _: P(), state.params),
                opt=zero1_opt_specs(mesh, state.opt),
                step=P())
            # decay masks ride in P(data)-spec'd like the opt buffers, so
            # each rank stores only its shard of the full-bucket masks
            # (grads share the params' shapes, hence the same plan).
            plan = _zero1_plan(state.params, num_streams=num_streams,
                               align=bucket_align, pack=pack,
                               schedule=schedule)
            masks = tuple(jnp.asarray(m) for m in bucket_decay_masks(plan))
            dpe = dp_entry(dp)
            step_z1 = (inner_step_zero1_overlap if schedule == "overlap"
                       else inner_step_zero1)
            f = shard_map(step_z1, mesh=mesh,
                          in_specs=(state_spec, batch_spec,
                                    tuple(P(dpe) for _ in masks)),
                          out_specs=(state_spec, metric_specs),
                          check_vma=False, axis_names=set(dp))
            return f(state, batch, masks)
        state_spec = jax.tree_util.tree_map(lambda _: P(), state)
        step_rep = inner_step_overlap if schedule == "overlap" else inner_step
        f = shard_map(step_rep, mesh=mesh,
                      in_specs=(state_spec, batch_spec),
                      out_specs=(state_spec, metric_specs),
                      check_vma=False, axis_names=set(dp))
        return f(state, batch)

    return train_step
