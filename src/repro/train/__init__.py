from repro.train.losses import cross_entropy, total_loss
from repro.train.trainer import TrainState, make_train_step, train_state_init

__all__ = ["TrainState", "cross_entropy", "make_train_step", "total_loss",
           "train_state_init"]
