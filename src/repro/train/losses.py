"""Loss functions: masked next-token CE + MoE aux terms."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import PAD_LABEL

LOAD_BALANCE_COEF = 0.01
ROUTER_Z_COEF = 1e-3


def cross_entropy(logits, labels) -> Tuple[jax.Array, jax.Array]:
    """Masked CE. logits: (..., S, V); labels: (..., S) with PAD_LABEL masked.
    Returns (sum_loss, num_tokens)."""
    mask = labels != PAD_LABEL
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = -jnp.where(mask, ll, 0.0)
    return loss.sum(), mask.sum()


def total_loss(cfg: ModelConfig, logits, labels, aux: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    ce_sum, n = cross_entropy(logits, labels)
    ce = ce_sum / jnp.maximum(n, 1)
    loss = ce
    # fixed metric structure (so distributed out_specs are static)
    lb = aux.get("load_balance", jnp.zeros(())) / max(1, cfg.num_layers)
    rz = aux.get("router_z", jnp.zeros(())) / max(1, cfg.num_layers)
    if cfg.moe is not None:
        loss = loss + LOAD_BALANCE_COEF * lb + ROUTER_Z_COEF * rz
    metrics = {"ce": ce, "tokens": n.astype(jnp.float32),
               "load_balance": lb, "router_z": rz, "loss": loss}
    return loss, metrics
