"""mixtral-8x22b [arXiv:2401.04088] — 8-expert top-2 MoE, GQA, SWA
(per the assignment spec)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32_768,
    hidden_act="silu",
    norm="rmsnorm",
    sliding_window=4096,     # SWA per assignment
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088 (Mixtral)",
)
