"""mamba2-780m [arXiv:2405.21060] — attention-free SSD (state-space duality)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    source="arXiv:2405.21060 (Mamba2 / SSD)",
)
