"""olmo-1b [arXiv:2402.00838] — dense, non-parametric LayerNorm (no scale/bias)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    hidden_act="silu",
    norm="nonparametric",    # OLMo LN without affine params
    use_bias=False,
    tie_embeddings=True,
    source="arXiv:2402.00838 (OLMo)",
)
