"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
decoder + CLIP vision frontend. Per the assignment carve-out the vision
encoder is a STUB: input_specs() provides precomputed patch embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    hidden_act="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    modality="vlm",
    num_patches=576,         # 24x24 CLIP patch grid per image tile
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
