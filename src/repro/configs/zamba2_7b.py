"""zamba2-7b [arXiv:2411.15242] — hybrid: Mamba2 backbone with a
shared-weight attention block interleaved every N blocks."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,            # d_model / num_heads
    d_ff=14336,
    vocab_size=32_000,
    hidden_act="gelu",
    norm="rmsnorm",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=256),
    hybrid_attn_every=6,     # shared attention block every 6 mamba blocks
    source="arXiv:2411.15242 (Zamba2)",
)
