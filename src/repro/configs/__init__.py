"""Architecture registry.

``get_config("<arch-id>")`` resolves the 10 assigned architectures (by their
public ids, e.g. ``gemma-2b``) plus variant suffixes:

* ``<id>-smoke``    — reduced same-family config for CPU smoke tests
* ``<id>-swa<W>``   — sliding-window variant (used by full-attention archs
                      for the ``long_500k`` decode shape)
"""

from __future__ import annotations

import importlib
import re
from typing import Dict, List

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    flops_per_token,
    human,
)

_ARCH_MODULES = {
    "gemma-2b": "gemma_2b",
    "yi-9b": "yi_9b",
    "command-r-35b": "command_r_35b",
    "zamba2-7b": "zamba2_7b",
    "mamba2-780m": "mamba2_780m",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "mixtral-8x22b": "mixtral_8x22b",
    "olmo-1b": "olmo_1b",
    "arctic-480b": "arctic_480b",
    "musicgen-large": "musicgen_large",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)

# Archs whose base attention is already sub-quadratic-compatible at 500k:
# pure SSM (no attention at all) or natively sliding-window. Every other
# arch (incl. the zamba2 hybrid's shared attention block) runs long_500k
# through the -swa4096 variant.
SUBQUADRATIC_AT_500K = {"mamba2-780m", "mixtral-8x22b"}

_SWA_RE = re.compile(r"^(?P<base>.+?)-swa(?P<win>\d+)$")


def get_config(arch: str) -> ModelConfig:
    smoke = arch.endswith("-smoke")
    if smoke:
        arch = arch[: -len("-smoke")]
    m = _SWA_RE.match(arch)
    window = None
    if m and m.group("base") in _ARCH_MODULES:
        arch, window = m.group("base"), int(m.group("win"))
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; known: {', '.join(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    if window is not None:
        cfg = cfg.with_sliding_window(window)
    if smoke:
        cfg = cfg.smoke()
    return cfg


def config_for_shape(arch: str, shape: str) -> ModelConfig:
    """Resolve the config actually used for an (arch x input-shape) pair.

    ``long_500k`` requires sub-quadratic attention. SSM/hybrid/SWA archs run
    as-is; full-attention archs run their sliding-window variant (the
    "dense archs only if you implement a sliding-window variant" clause).
    """
    cfg = get_config(arch)
    if shape == "long_500k" and arch in _ARCH_MODULES:
        if arch not in SUBQUADRATIC_AT_500K and cfg.family != "ssm":
            cfg = cfg.with_sliding_window(4096)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "SUBQUADRATIC_AT_500K",
    "all_configs",
    "config_for_shape",
    "flops_per_token",
    "get_config",
    "human",
]
