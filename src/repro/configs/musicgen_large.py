"""musicgen-large [arXiv:2306.05284] — decoder-only transformer over EnCodec
tokens (4 codebooks, delay pattern). The EnCodec frontend is a STUB per the
assignment carve-out; the model consumes/emits codebook token streams."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,         # EnCodec codebook size
    hidden_act="gelu",
    norm="layernorm",
    use_bias=True,
    modality="audio",
    num_codebooks=4,
    source="arXiv:2306.05284 (MusicGen)",
)
