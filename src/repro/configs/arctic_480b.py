"""arctic-480b [hf:Snowflake/snowflake-arctic-base] — 128-expert top-2 MoE
with a parallel dense residual FFN on every layer ("dense-MoE hybrid")."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    hidden_act="silu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual=True),
    optimizer_dtype="bfloat16",   # fp32 moments would not fit 256 chips
    source="hf:Snowflake/snowflake-arctic-base",
)
