"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no bias,
parallel attention+FFN block, LayerNorm, tied embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256_000,
    hidden_act="silu",
    norm="layernorm",
    use_bias=False,
    parallel_block=True,     # Cohere parallel residual block
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
