"""gemma-2b [arXiv:2403.08295] — dense, GeGLU, head_dim=256, MQA (kv=1)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,          # MQA on the 2b model
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    hidden_act="gelu",       # GeGLU
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="arXiv:2403.08295 (Gemma)",
)
