"""Config dataclasses for the repro framework.

Every assigned architecture gets one module in this package defining a
``CONFIG: ModelConfig`` with the exact published numbers (citation in the
module docstring). The smoke-test reduction (``smoke()``) preserves the
*family* (dense/moe/ssm/hybrid/vlm/audio) while shrinking every dimension to
CPU scale, per the assignment (<=2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    # inference headroom: static-shape TPU MoE requires a capacity bound;
    # drops under extreme router skew are the documented approximation
    # (GShard/Switch semantics). Tests that need exactness set this to
    # num_experts, which makes C >= S (provably drop-free).
    capacity_factor_eval: float = 2.0
    # Arctic keeps a small dense ("residual") FFN in parallel with the MoE
    # FFN on every layer [hf:Snowflake/snowflake-arctic-base].
    dense_residual: bool = False


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD parameters [arXiv:2405.21060]."""

    d_state: int = 128
    head_dim: int = 64          # SSD "P" — value-head dim
    expand: int = 2             # d_inner = expand * d_model
    chunk_size: int = 256       # SSD chunk length for the blocked scan
    conv_width: int = 4         # causal depthwise conv window
    ngroups: int = 1            # B/C groups (GVA); 1 == multi-value attention

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- block structure ---------------------------------------------------
    hidden_act: str = "silu"     # "gelu" => GeGLU gating, "silu" => SwiGLU
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparametric
    use_bias: bool = False
    tie_embeddings: bool = False
    parallel_block: bool = False  # attention and FFN in parallel (command-r)
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # SWA window; None => full causal

    # --- mixtures / state-space / hybrid ------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared-weight* attention block applied every
    # ``hybrid_attn_every`` backbone blocks [arXiv:2411.15242].
    hybrid_attn_every: int = 0

    # --- modality frontends (stubbed per the assignment carve-out) ----------
    modality: str = "text"       # text | vlm | audio
    num_patches: int = 0         # VLM: precomputed patch embeddings per image
    num_codebooks: int = 1       # audio: EnCodec codebook streams

    # --- numerics / memory ---------------------------------------------------
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "bfloat16"    # stored parameter dtype
    optimizer_dtype: str = "float32"  # Adam moment dtype (arctic: bfloat16)
    remat: str = "block"             # none | block | full

    # --- beyond-paper optimization toggles (EXPERIMENTS.md §Perf) -----------
    # "moe_dispatch"  shard the MoE dispatch buffer over the batch axes when
    #                 experts don't divide (baseline replicates it — the
    #                 Fig-17-style mapping mismatch, at the sharding level)
    # "decode_cache"  force the in-model KV-cache constraint to match the
    #                 input layout exactly (kills involuntary resharding)
    # "fsdp"          pure-FSDP parameter layout over (data x model) instead
    #                 of TP(model) x FSDP(data) — wins when weight traffic
    #                 < activation all-reduce traffic
    # "bf16_grads"    custom-vjp boundary after each pre-matmul norm: the
    #                 backward TP all-reduces carry bf16 (not f32) payloads
    opts: Tuple[str, ...] = ()
    # OPT(decode_cache): store each KV head ``decode_kv_expand`` times so
    # stored heads == TP degree — the cache shards over 'model' exactly like
    # the q heads, decode attention is fully local, and the per-token cache
    # write lands on an UNsharded dim (no involuntary gather). 2x KV memory.
    decode_kv_expand: int = 1

    # citation for the exact numbers above
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family in ("ssm",):
            assert self.num_heads == 0 and self.ssm is not None
        if self.family in ("moe",):
            assert self.moe is not None
        if self.family == "hybrid":
            assert self.ssm is not None and self.hybrid_attn_every > 0
        if self.num_heads:
            assert self.head_dim * self.num_heads >= self.d_model // 2

    # --- derived sizes -------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def attn_params(self) -> int:
        if self.num_heads == 0:
            return 0
        return self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model

    def ffn_params_dense(self, d_ff: Optional[int] = None) -> int:
        d_ff = self.d_ff if d_ff is None else d_ff
        return 3 * self.d_model * d_ff  # gated (w_gate, w_up, w_down)

    def ssm_params(self) -> int:
        if self.ssm is None:
            return 0
        c = self.ssm
        d_in = c.d_inner(self.d_model)
        nheads = c.num_heads(self.d_model)
        # in_proj emits [z, x, B, C, dt]; out_proj returns to d_model.
        d_bc = 2 * c.ngroups * c.d_state
        in_proj = self.d_model * (2 * d_in + d_bc + nheads)
        conv = (d_in + d_bc) * c.conv_width
        return in_proj + conv + nheads * 2 + d_in * self.d_model  # + A, D + out

    def layer_params(self) -> int:
        """Parameters of ONE backbone layer (attention archs) or block (ssm)."""
        if self.family == "ssm":
            return self.ssm_params()
        p = self.attn_params()
        if self.moe is not None:
            p += self.moe.num_experts * self.ffn_params_dense()
            p += self.d_model * self.moe.num_experts  # router
            if self.moe.dense_residual:
                p += self.ffn_params_dense()
        else:
            p += self.ffn_params_dense()
        return p

    def param_count(self) -> int:
        """Approximate total params (embeddings + layers + head)."""
        embed = self.vocab_size * self.d_model * self.num_codebooks
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model * self.num_codebooks
        if self.family == "hybrid":
            body = self.num_layers * self.ssm_params()
            # ONE shared attention block (+ its FFN), reused at each interleave
            shared = self.attn_params() + self.ffn_params_dense()
            body += shared  # weights are shared => counted once
        else:
            body = self.num_layers * self.layer_params()
        return embed + head + body

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_layer_active = self.attn_params() + m.top_k * self.ffn_params_dense()
        per_layer_active += self.d_model * m.num_experts
        if m.dense_residual:
            per_layer_active += self.ffn_params_dense()
        embed = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        return embed + head + self.num_layers * per_layer_active

    # ------------------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=256,
            d_ff=0 if self.family == "ssm" else 512,
            vocab_size=512,
            num_heads=0 if self.num_heads == 0 else 4,
            num_kv_heads=0 if self.num_heads == 0 else min(self.num_kv_heads, 2),
            head_dim=64,
            num_patches=min(self.num_patches, 16),
            sliding_window=None if self.sliding_window is None else 64,
            dtype="float32",
            param_dtype="float32",
            remat="none",
        )
        if self.moe is not None:
            changes["moe"] = replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2)
            )
        if self.ssm is not None:
            changes["ssm"] = replace(self.ssm, d_state=16, head_dim=32, chunk_size=32)
        if self.hybrid_attn_every:
            changes["hybrid_attn_every"] = 2
        return replace(self, **changes)

    def with_opts(self, *opts: str) -> "ModelConfig":
        known = {"moe_dispatch", "decode_cache", "fsdp", "bf16_grads",
                 "serve_resident", "kv_fp8"}
        bad = set(opts) - known
        if bad:
            raise ValueError(f"unknown opts {bad}; known: {known}")
        return replace(self, opts=tuple(sorted(set(self.opts) | set(opts))))

    def with_sliding_window(self, window: int = 4096) -> "ModelConfig":
        """SWA variant used by full-attention archs for the long_500k shape."""
        if self.sliding_window is not None and self.sliding_window <= window:
            return self
        return replace(self, name=self.name + f"-swa{window}", sliding_window=window)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS/token = 6*N (dense) or 6*N_active (MoE) [Kaplan/Chinchilla]."""
    return 6.0 * cfg.active_param_count()


def human(n: float) -> str:
    for unit in ("", "K", "M", "B", "T", "P", "E"):
        if abs(n) < 1000:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Z"
