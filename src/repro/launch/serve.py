"""Serving driver: continuous-batching prefill + decode on any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b-smoke \
        --requests 8 --prompt-len 32 --max-new 32

Serve-path VCI streams (manual TP, collectives on per-purpose CommContexts):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b-smoke \
        --tp 2 --num-vcis 8 --policy fcfs --temperature 0.8 --stop 17

Paged KV cache (pool of fixed-size pages + per-slot page table; mid-stream
admission then also works under the mesh):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b-smoke \
        --tp 2 --vary-prompts --paged --page-size 16 --pages 40
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serve.comm import ServeCommPlan
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--vary-prompts", action="store_true",
                    help="draw prompt lengths in [prompt-len/2, prompt-len] "
                         "to exercise the left-padded mixed-length path")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="engine-default sampling temperature (0 = greedy)")
    ap.add_argument("--stop", type=int, default=None,
                    help="stop token id applied to every request")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree; >1 builds a (data, model) "
                         "mesh and runs decode on VCI streams")
    ap.add_argument("--num-vcis", type=int, default=8,
                    help="VCI pool size for the serve comm plan (tp>1)")
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "round_robin", "hash", "hinted"),
                    help="VCI pool assignment policy (tp>1)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (page pool + per-slot page table); "
                         "mid-stream admission then works under --tp too")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per page (paged cache)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size incl. the trash page (default: "
                         "full provision batch*ceil(max_len/page_size)+1)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    mesh = comm_plan = None
    if args.tp > 1:
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) % args.tp:
            raise SystemExit(f"{len(devs)} devices do not split into tp="
                             f"{args.tp} (set XLA_FLAGS host device count)")
        mesh = Mesh(np.array(devs).reshape(len(devs) // args.tp, args.tp),
                    ("data", "model"))
        comm_plan = ServeCommPlan(num_vcis=args.num_vcis,
                                  vci_policy=args.policy)
        print(f"mesh=data{mesh.shape['data']}xmodel{args.tp} "
              f"num_vcis={args.num_vcis} policy={args.policy}")

    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len, mesh=mesh,
                         comm_plan=comm_plan, temperature=args.temperature,
                         seed=args.seed, paged=args.paged,
                         page_size=args.page_size, num_pages=args.pages)
    if args.paged:
        if not engine._paged:
            raise SystemExit(
                f"--paged requested but arch {cfg.name!r} has no paged "
                f"layout (ring/SSM/audio/VLM caches fall back to grouped "
                f"contiguous batches) — drop --paged or pick an attention "
                f"arch with max_len <= its sliding window")
        print(f"paged cache: page_size={args.page_size} "
              f"num_pages={engine._num_pages} "
              f"(admit_under_mesh={engine._can_admit})")

    rng = np.random.default_rng(args.seed)
    reqs = []
    for _ in range(args.requests):
        plen = (int(rng.integers(max(1, args.prompt_len // 2),
                                 args.prompt_len + 1))
                if args.vary_prompts else args.prompt_len)
        shape = ((cfg.num_codebooks, plen)
                 if cfg.modality == "audio" else (plen,))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, shape, dtype=np.int32),
            max_new_tokens=args.max_new, stop_token=args.stop))

    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(r.generated.shape[-1] for r in done)
    print(f"{len(done)} requests, {n_tok} new tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s) "
          f"cache_bytes_resident={engine.cache_bytes_resident}")
    if comm_plan is not None:
        s = comm_plan.stats
        print(f"vci stats: acquires={s.acquires} fallback_hits="
              f"{s.fallback_hits} max_contexts_per_vci="
              f"{s.max_contexts_per_vci} map={comm_plan.vci_map()}")
    for i, r in enumerate(done[:4]):
        tail = r.generated[..., :8]
        print(f"  req{i}: first tokens {tail.tolist()}")


if __name__ == "__main__":
    main()
