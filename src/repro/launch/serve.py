"""Serving driver: batched prefill + decode on any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b-smoke \
        --requests 8 --prompt-len 32 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    shape = ((cfg.num_codebooks, args.prompt_len)
             if cfg.modality == "audio" else (args.prompt_len,))
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, shape,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]

    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(r.generated.shape[-1] for r in done)
    print(f"{len(done)} requests, {n_tok} new tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for i, r in enumerate(done[:4]):
        tail = r.generated[..., :8]
        print(f"  req{i}: first tokens {tail.tolist()}")


if __name__ == "__main__":
    main()
