"""Aggregate reports/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--dir reports/dryrun]

Emits (markdown): §Dry-run summary (per-device memory + collective schedule)
and the §Roofline table (three terms, dominant, model-FLOPs ratio, and a
what-would-move-it note per row).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def _fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}us"


def _fmt_b(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def suggestion(row: Dict) -> str:
    dom = row["dominant"]
    shape = row["shape"]
    if dom == "compute":
        if row.get("model_ratio", 1) < 0.5:
            return "recompute waste: relax remat policy / recompute less"
        return "compute-bound at high useful-FLOPs ratio: near roofline; " \
               "try more chips or lower precision"
    if dom == "memory":
        if "decode" in shape or shape == "long_500k":
            return "KV/state reads dominate: shrink cache dtype (int8/fp8), " \
                   "or shard sequence further"
        return "increase arithmetic intensity: larger per-chip batch/fusion"
    # collective
    if shape == "train_4k":
        return "gradient/FSDP traffic: overlap collectives with compute, " \
               "bigger buckets, or rebalance data-vs-model axes"
    if "decode" in shape or shape == "long_500k":
        return "TP all-reduces dominate tiny decode step: shrink model " \
               "axis for decode or batch requests"
    return "prefill TP traffic: overlap all-gathers with layer compute"


def load(dir_: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 | 2x16x16")
    args = ap.parse_args()
    rows = load(args.dir)
    ok = [r for r in rows if r.get("status") == "ok"]
    fails = [r for r in rows if r.get("status") != "ok"]

    print(f"## Dry-run summary: {len(ok)} ok / {len(fails)} failed "
          f"of {len(rows)} (arch x shape x mesh)\n")
    if fails:
        for r in fails:
            print(f"- FAIL {r.get('requested_arch')} {r.get('shape')} "
                  f"{r.get('mesh')}: {r.get('error')}")
        print()

    sel = [r for r in ok if args.mesh is None or r["mesh"] == args.mesh]
    sel.sort(key=lambda r: (r["requested_arch"],
                            SHAPE_ORDER.get(r["shape"], 9), r["mesh"]))

    print("| arch | shape | mesh | compute | memory | collective | dominant "
          "| MODEL/HLO | per-dev argbytes | coll. ops (count/depth) | "
          "what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sel:
        mem = r.get("memory_per_chip") or {}
        st = (r.get("collectives") or {}).get("_structure", {})
        print(f"| {r['requested_arch']} | {r['shape']} | {r['mesh']} "
              f"| {_fmt_t(r['t_compute_s'])} | {_fmt_t(r['t_memory_s'])} "
              f"| {_fmt_t(r['t_collective_s'])} | **{r['dominant']}** "
              f"| {r['model_ratio']:.2f} "
              f"| {_fmt_b(mem.get('argument_bytes'))} "
              f"| {st.get('collective_count', 0):.0f}/"
              f"{st.get('critical_depth', 0):.0f} "
              f"| {suggestion(r)} |")

    # aggregate collective schedule
    print("\n### Collective schedule (per-kind link-bytes, single-pod)\n")
    agg: Dict[str, Dict[str, float]] = {}
    for r in sel:
        if r["mesh"] != "16x16":
            continue
        for kind, d in (r.get("collectives") or {}).items():
            if kind.startswith("_"):
                continue
            a = agg.setdefault(kind, {"count": 0, "link_bytes": 0.0})
            a["count"] += d["count"]
            a["link_bytes"] += d["link_bytes"]
    print("| kind | total ops | total link-bytes |")
    print("|---|---|---|")
    for kind, d in sorted(agg.items()):
        print(f"| {kind} | {d['count']:.0f} | {_fmt_b(d['link_bytes'])} |")


if __name__ == "__main__":
    main()
