"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and the dry-run
needs to set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small CPU mesh for tests/benchmarks (requires the host-device flag)."""
    n = len(jax.devices())
    data = min(data, max(1, n // model))
    return jax.make_mesh((data, model), ("data", "model"))
