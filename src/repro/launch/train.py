"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b-smoke \
        --steps 50 --batch 8 --seq 128 --comm vci --progress hybrid

Runs on whatever devices are visible (1 CPU here; a real TPU slice in
production — the same code path, with ``--mesh`` picking the production
topology). ``--comm vci`` engages the paper's bucketed VCI gradient
reduction; ``--comm gspmd`` is the XLA-native baseline.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.io import latest_step, load_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import synthetic_batch
from repro.optim.schedule import cosine_schedule
from repro.train.trainer import make_train_step, train_state_init


def build_mesh(spec: str):
    if spec == "none" or not spec:
        return None
    from jax.sharding import Mesh
    dims = [int(d) for d in spec.split("x")]
    names = {1: ("data",), 2: ("data", "model"),
             3: ("pod", "data", "model")}[len(dims)]
    devs = np.array(jax.devices()[: int(np.prod(dims))]).reshape(dims)
    return Mesh(devs, names)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b-smoke",
                    help=f"one of {ARCH_IDS} (+ -smoke / -swa<W> suffixes)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none", help='e.g. "8" or "4x2"')
    ap.add_argument("--comm", choices=("gspmd", "vci"), default="gspmd")
    ap.add_argument("--progress", choices=("global", "per_vci", "hybrid"),
                    default="hybrid")
    ap.add_argument("--vci-policy", default="fcfs")
    ap.add_argument("--num-streams", type=int, default=8)
    ap.add_argument("--pack", choices=("xla", "pallas"), default="xla",
                    help="bucket pack impl: concat chain vs tile-DMA layout")
    ap.add_argument("--reduction", choices=("all_reduce", "reduce_scatter"),
                    default="all_reduce")
    ap.add_argument("--optimizer", choices=("replicated", "zero1"),
                    default="replicated",
                    help="zero1 = ZeRO-1 sharded AdamW consuming the "
                         "reduce_scatter shards directly (vci mode only)")
    ap.add_argument("--zero1-wire", default=None,
                    help="wire dtype for zero1 grad-scatter/param-gather "
                         "(e.g. bfloat16); default f32")
    ap.add_argument("--overlap", action="store_true",
                    help="bucket-ready overlap scheduling (vci mode only): "
                         "issue each bucket's reduce inside the backward on "
                         "its VCI stream as soon as its grads exist, instead "
                         "of one post-backward reduction pass")
    ap.add_argument("--per-step-plan", action="store_true",
                    help="rebuild the comm plan every trace (seed behaviour; "
                         "default uses the persistent CommPlan cache)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = build_mesh(args.mesh)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())} mesh={args.mesh} comm={args.comm}")

    lr_fn = lambda s: cosine_schedule(s, peak=args.lr,
                                      warmup_steps=args.warmup,
                                      total_steps=args.steps)
    schedule = "overlap" if args.overlap else "post"
    step_fn = make_train_step(
        cfg, mesh=mesh, lr_fn=lr_fn, comm=args.comm, accum_steps=args.accum,
        num_streams=args.num_streams, progress=args.progress,
        vci_policy=args.vci_policy,
        pack=args.pack, reduction=args.reduction,
        persistent_plan=not args.per_step_plan,
        optimizer=args.optimizer, zero1_wire_dtype=args.zero1_wire,
        schedule=schedule,
        token_impl="data" if jax.default_backend() == "cpu" else "barrier")
    step = jax.jit(step_fn)

    state = train_state_init(
        cfg, jax.random.PRNGKey(args.seed), optimizer=args.optimizer,
        mesh=mesh, num_streams=args.num_streams, pack=args.pack,
        schedule=schedule)
    start = 0
    if args.ckpt_dir and (ls := latest_step(args.ckpt_dir)) is not None:
        state = load_checkpoint(args.ckpt_dir, ls, state)
        start = ls
        print(f"resumed from step {ls}")

    t0 = time.time()
    tokens_done = 0
    for i in range(start, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=args.seed,
                                step=i)
        state, metrics = step(state, batch)
        tokens_done += args.batch * args.seq
        if (i + 1) % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {i+1:5d}  loss {loss:7.4f}  ce {float(metrics['ce']):7.4f}  "
                  f"gnorm {float(metrics['grad_norm']):6.3f}  "
                  f"tok/s {tokens_done/dt:9.0f}", flush=True)
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, state,
                            metadata={"arch": cfg.name})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state,
                        metadata={"arch": cfg.name})
        print(f"checkpoint -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
