"""Abstract inputs + shardings for every (arch x input-shape x mesh) combo.

Everything here is ``ShapeDtypeStruct``-based (the shannon/kernels pattern):
weak-type-correct, shardable, zero device allocation — the dry-run lowers
and compiles against these stand-ins.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.data.pipeline import batch_spec
from repro.dist.sharding import data_axes, param_specs
from repro.models.transformer import init_cache, init_params
from repro.optim.adamw import AdamWState
from repro.train.trainer import TrainState


def _ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _tree_struct(f, *args):
    return jax.eval_shape(f, *args)


# ---------------------------------------------------------------------------
# parameters / train state
# ---------------------------------------------------------------------------

def params_struct(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return _tree_struct(lambda k: init_params(cfg, k), key)


def params_shardings(cfg: ModelConfig, mesh: Mesh):
    specs = param_specs(cfg, mesh)
    struct = params_struct(cfg)
    # verify the spec tree covers the param tree exactly
    sd = jax.tree_util.tree_structure(struct)
    ss = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    if sd != ss:
        raise ValueError(
            f"param spec tree mismatch for {cfg.name}:\n{sd}\nvs\n{ss}")
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), specs,
        is_leaf=lambda x: isinstance(x, P))


def train_state_struct(cfg: ModelConfig) -> TrainState:
    p = params_struct(cfg)
    mdt = jnp.dtype(cfg.optimizer_dtype)
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p)
    return TrainState(
        params=p,
        opt=AdamWState(m=mom, v=jax.tree_util.tree_map(lambda x: x, mom),
                       count=jax.ShapeDtypeStruct((), jnp.int32)),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def train_state_shardings(cfg: ModelConfig, mesh: Mesh) -> TrainState:
    ps = params_shardings(cfg, mesh)
    rep = NamedSharding(mesh, P())
    return TrainState(
        params=ps,
        opt=AdamWState(m=ps, v=jax.tree_util.tree_map(lambda x: x, ps),
                       count=rep),
        step=rep,
    )


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def batch_struct_and_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    spec = batch_spec(cfg, shape, mesh)
    dp = data_axes(mesh, cfg)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))

    shardings = {}
    for k, st in spec.items():
        lead = dp if (st.shape[0] % dpn == 0 and st.shape[0] >= dpn) else None
        shardings[k] = _ns(mesh, lead, *([None] * (len(st.shape) - 1)))
    return spec, shardings


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def cache_struct(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    return _tree_struct(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype=dtype))


def cache_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """Sharding rules for the stacked decode cache (leading L/site dim).

    * batch over (pod, data) when it divides;
    * KV heads over model when they divide, else sequence over model;
    * long_500k (batch=1): sequence over ALL axes — single-stream decode has
      no batch parallelism, the cache is the only shardable state.
    """
    dp = data_axes(mesh, cfg)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    tp = mesh.shape.get("model", 1)
    struct = cache_struct(cfg, shape)

    def kv_spec(st):  # (L, B, S, KV, hd)
        _, b, s, kv, _ = st.shape
        if b % dpn == 0 and b >= dpn:
            lead = dp
            head = "model" if kv % tp == 0 else None
            if head is None and s % tp == 0:
                return P(None, lead, "model", None, None)
            return P(None, lead, None, head, None)
        # batch too small: shard sequence over everything that divides
        seq_axes = tuple(dp) + ("model",)
        total = dpn * tp
        if s % total == 0:
            return P(None, None, seq_axes, None, None)
        if s % tp == 0:
            return P(None, None, "model", None, None)
        return P(None, None, None, None, None)

    def ssm_conv_spec(st):  # (L, B, W-1, CH)
        _, b, _, ch = st.shape
        lead = dp if (b % dpn == 0 and b >= dpn) else None
        return P(None, lead, None, "model" if ch % tp == 0 else None)

    def ssm_ssd_spec(st):  # (L, B, H, N, P)
        _, b, h, _, _ = st.shape
        lead = dp if (b % dpn == 0 and b >= dpn) else None
        return P(None, lead, "model" if h % tp == 0 else None, None, None)

    def assign(path, st):
        keys = tuple(str(getattr(k, "key", getattr(k, "name", k))) for k in path)
        if st.shape == ():
            return NamedSharding(mesh, P())
        if "kv" in keys:
            return NamedSharding(mesh, kv_spec(st))
        if "conv" in keys:
            return NamedSharding(mesh, ssm_conv_spec(st))
        if "ssd" in keys:
            return NamedSharding(mesh, ssm_ssd_spec(st))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, struct)


def decode_token_struct(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    if cfg.modality == "audio":
        return jax.ShapeDtypeStruct((b, cfg.num_codebooks, 1), jnp.int32)
    return jax.ShapeDtypeStruct((b, 1), jnp.int32)


def decode_token_sharding(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    dp = data_axes(mesh, cfg)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    b = shape.global_batch
    lead = dp if (b % dpn == 0 and b >= dpn) else None
    extra = 1 if cfg.modality == "audio" else 0
    return _ns(mesh, lead, *([None] * (1 + extra)))
