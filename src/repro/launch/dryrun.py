import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder host devices.
Nothing here allocates device memory — inputs are ShapeDtypeStructs.

Per combination this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. jits train_step (train shape) or serve_step (+ prefill lowering for
     prefill shapes) with explicit in/out shardings,
  3. ``.lower().compile()`` — any sharding mismatch / unsupported collective
     fails loudly here,
  4. records memory_analysis(), cost_analysis() and the HLO collective
     schedule into a JSON report consumed by EXPERIMENTS.md §Dry-run and the
     roofline table (§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod|--both]
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, config_for_shape
from repro.launch import inputs as I
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline
from repro.train.trainer import make_train_step
from repro.serve.engine import make_serve_step
from repro.compat import set_mesh


def _memory_dict(compiled) -> Optional[dict]:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception:
        return None


def _cost_dict(compiled) -> Optional[dict]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception:
        return None


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool,
               keep_hlo: bool = False, opts: tuple = ()) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(arch, shape_name)
    if opts:
        import dataclasses
        remats = [o for o in opts if o.startswith("remat:")]
        real = tuple(o for o in opts if not o.startswith("remat:"))
        if real:
            cfg = cfg.with_opts(*real)
        for r in remats:
            cfg = dataclasses.replace(cfg, remat=r.split(":", 1)[1])
        if "decode_cache" in cfg.opts:
            tp = 16  # model-axis size of both production meshes
            kv = cfg.num_kv_heads
            # only when the cache batch-shards over data (else the seq dim
            # stays sharded and expansion just doubles the gathered bytes —
            # measured regression on long_500k, EXPERIMENTS §Perf)
            batch_shards = shape.global_batch % tp == 0 \
                and shape.global_batch >= tp
            if (batch_shards and cfg.num_heads and kv and kv < tp
                    and tp % kv == 0 and cfg.num_heads % tp == 0):
                cfg = dataclasses.replace(cfg, decode_kv_expand=tp // kv)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    t0 = time.time()

    if shape.kind == "train":
        state_struct = I.train_state_struct(cfg)
        state_sh = I.train_state_shardings(cfg, mesh)
        batch_struct, batch_sh = I.batch_struct_and_shardings(cfg, shape, mesh)
        step = make_train_step(cfg, mesh=mesh, comm="gspmd")
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        with set_mesh(mesh):
            lowered = jitted.lower(state_struct, batch_struct)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        # prefill lowers the full forward producing the cache
        from repro.serve.engine import make_prefill
        params_struct = I.params_struct(cfg)
        params_sh = I.params_shardings(cfg, mesh)
        batch_struct, batch_sh = I.batch_struct_and_shardings(cfg, shape, mesh)
        cache_struct = I.cache_struct(cfg, shape)
        cache_sh = I.cache_shardings(cfg, shape, mesh)
        fn = make_prefill(cfg, mesh)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, batch_sh, cache_sh),
            out_shardings=(I.decode_token_sharding(cfg, shape, mesh), cache_sh),
            donate_argnums=(2,),
        )
        with set_mesh(mesh):
            lowered = jitted.lower(params_struct, batch_struct, cache_struct)
            compiled = lowered.compile()
    else:  # decode
        params_struct = I.params_struct(cfg)
        params_sh = I.params_shardings(cfg, mesh)
        tok_struct = I.decode_token_struct(cfg, shape)
        tok_sh = I.decode_token_sharding(cfg, shape, mesh)
        cache_struct = I.cache_struct(cfg, shape)
        cache_sh = I.cache_shardings(cfg, shape, mesh)
        fn = make_serve_step(cfg, mesh)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, tok_sh, cache_sh),
            out_shardings=(tok_sh, cache_sh),
            donate_argnums=(2,),
        )
        with set_mesh(mesh):
            lowered = jitted.lower(params_struct, tok_struct, cache_struct)
            compiled = lowered.compile()

    hlo = compiled.as_text()
    mem = _memory_dict(compiled)
    cost = _cost_dict(compiled)
    rl = build_roofline(cfg, shape, mesh_name, chips, hlo, cost, mem)
    out = rl.row()
    out["requested_arch"] = arch
    out["compile_s"] = time.time() - t0
    out["status"] = "ok"
    if keep_hlo:
        out["hlo"] = hlo
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--opt", default="",
                    help="comma-separated optimization toggles "
                         "(moe_dispatch,decode_cache,fsdp) — §Perf variants")
    ap.add_argument("--stable", action="store_true",
                    help="deterministic reports: drop wall-clock fields "
                         "(compile_s) so a re-run diffs clean against the "
                         "committed reports/dryrun_baseline — the CI "
                         "dryrun-drift job runs with this flag")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if opts:
                    tag += "__opt_" + "_".join(opts)
                try:
                    row = lower_pair(arch, shape, multi_pod=mp, opts=opts)
                    dom = row["dominant"]
                    print(f"[ok] {tag:55s} compile={row['compile_s']:.1f}s "
                          f"dom={dom} "
                          f"C/M/K={row['t_compute_s']:.3g}/"
                          f"{row['t_memory_s']:.3g}/"
                          f"{row['t_collective_s']:.3g}s", flush=True)
                except Exception as e:
                    failures += 1
                    row = {"requested_arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "fail", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                if args.stable:
                    row.pop("compile_s", None)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(row, f, indent=1, default=str)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
