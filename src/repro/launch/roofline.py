"""Roofline accounting from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds:

    compute    = FLOPs            / (chips * 197e12  bf16 FLOP/s)
    memory     = HBM bytes        / (chips * 819e9   B/s)
    collective = ICI link bytes   / (chips * 50e9    B/s per link)

Sources:

* **collective bytes** are parsed from the compiled HLO text. Models scan
  over layers, so collectives inside ``while`` bodies are multiplied by the
  loop trip count, recovered from the loop-condition computation's compare
  constant (XLA's canonical scan lowering); nested loops multiply through.
  Per-op link-byte models: all-reduce 2x, all-gather/reduce-scatter/
  all-to-all (n-1)/n x payload, collective-permute 1x.

* **FLOPs / HBM bytes** use the analytic workload model below.
  ``compiled.cost_analysis()`` counts a while body ONCE (XLA HloCostAnalysis
  semantics), which under layer-scan underestimates by ~L x; we therefore
  report the analytic value as the roofline term and the raw HLO number as a
  cross-check column. MODEL_FLOPS = 6·N_active·D is reported alongside as
  the useful-compute ratio.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional


from repro.configs.base import InputShape, ModelConfig

# TPU v5e
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

def _shape_bytes(sig: str) -> int:
    """Bytes of an HLO type signature like ``bf16[16,128]{1,0}`` or a tuple."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", sig):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


@dataclass
class CollectiveOp:
    kind: str
    bytes_payload: int      # per-device payload (SPMD shapes are per-device)
    group_size: int
    computation: str
    multiplier: int = 1

    @property
    def link_bytes(self) -> float:
        """Per-chip link traffic. SPMD operand shapes are per-device:
        all-gather's operand is the SHARD (each chip ships it n-1 times in a
        ring), while all-reduce / reduce-scatter / all-to-all operands are
        the full per-device buffer (ring cost (n-1)/n x buffer, 2x for AR).
        """
        n = max(self.group_size, 1)
        if self.kind == "all-reduce":
            f = 2.0 * (n - 1) / n
        elif self.kind == "all-gather":
            f = float(n - 1)
        elif self.kind in ("reduce-scatter", "all-to-all"):
            f = (n - 1) / n
        else:  # collective-permute
            f = 1.0
        return self.bytes_payload * f * self.multiplier


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, str] = {}
    cur = None
    buf: List[str] = []
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$", line)
        if m:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur = m.group(1)
            buf = []
        elif cur is not None:
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur = None
                buf = []
            else:
                buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def _group_size(attrs: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    return total_devices


def _while_trip_counts(comps: Dict[str, str]) -> Dict[str, int]:
    """while body computation -> trip count.

    Preferred source: XLA's ``backend_config={"known_trip_count":{"n":"L"}}``
    annotation on the while op. Fallback: the largest integer constant in the
    loop-condition computation (the canonical ``i < L`` compare).
    """
    trips: Dict[str, int] = {}
    for cname, body in comps.items():
        for m in re.finditer(
                r"while\([^)]*\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)"
                r"(.*)$",
                body, re.M):
            cond, wbody, rest = m.group(1), m.group(2), m.group(3)
            ktc = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', rest)
            if ktc:
                trips[wbody] = int(ktc.group(1))
                continue
            ctext = comps.get(cond, "")
            consts = [int(c) for c in re.findall(
                r"constant\((\d+)\)", ctext)]
            trips[wbody] = max(consts) if consts else 1
    return trips


def _call_multipliers(comps: Dict[str, str], entry: str) -> Dict[str, int]:
    """Effective execution multiplier per computation (nested whiles)."""
    trips = _while_trip_counts(comps)
    mult: Dict[str, int] = {entry: 1}
    # build call edges: computation -> called computations
    call_re = re.compile(
        r"(?:condition=|body=|to_apply=|called_computations=\{|calls=)"
        r"%?([\w.\-]+)")
    edges: Dict[str, List[str]] = {
        c: [m.group(1) for m in call_re.finditer(t) if m.group(1) in comps]
        for c, t in comps.items()
    }
    # BFS from entry, propagating multipliers; while bodies multiply by trip
    import collections
    q = collections.deque([entry])
    seen = {entry}
    while q:
        c = q.popleft()
        for callee in edges.get(c, []):
            m = mult[c] * trips.get(callee, 1)
            if callee not in mult or m > mult[callee]:
                mult[callee] = m
                if callee not in seen or m > 1:
                    q.append(callee)
                    seen.add(callee)
    return mult


def parse_collectives(hlo: str, total_devices: int) -> List[CollectiveOp]:
    comps = _split_computations(hlo)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m:
        entry = m.group(1)
    else:
        entry = next(iter(comps), "main")
    mult = _call_multipliers(comps, entry)

    ops: List[CollectiveOp] = []
    # result type may be a tuple `(f32[..], /*index=5*/f32[..])` when XLA's
    # collective combiner has batched independent streams into one op.
    op_re = re.compile(
        r"=\s+(\([^()]*\)|[^\s]+)\s+(" + "|".join(_COLLECTIVES) +
        r")(?:-start)?\(([^)]*)\)(.*)$")
    for cname, body in comps.items():
        for line in body.splitlines():
            mo = op_re.search(line)
            if not mo:
                continue
            out_sig, kind, operands, attrs = mo.groups()
            if "-done" in line:
                continue
            # payload: use operand shapes (result of AG is bigger by design)
            payload = _shape_bytes(operands)
            if payload == 0:
                payload = _shape_bytes(out_sig)
            ops.append(CollectiveOp(
                kind=kind,
                bytes_payload=payload,
                group_size=_group_size(attrs, total_devices),
                computation=cname,
                multiplier=mult.get(cname, 1),
            ))
    return ops


# op names are lowercase-with-dashes; requiring a leading lowercase letter
# avoids matching layout annotations like {1,0:T(8,128)}. The result type
# may be a tuple with /*index=k*/ comments (combined collectives), so the
# prefix skip is `.*?`, not `[^=]*?`.
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*.*?"
                       r"([a-z][a-z0-9\-]*)\((.*)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def collective_critical_depth(hlo: str) -> Dict[str, float]:
    """Longest dependency chain of collective ops (structural serialization).

    The paper's serialization story in one number: a global critical section
    chains EVERY message (depth == #messages); independent VCI streams chain
    only within a stream (depth == messages-per-stream); hybrid progress
    lands in between (the periodic join adds cross-stream edges).

    Depth is computed per computation from the def-use graph of the compiled
    HLO and scaled by the while-loop trip multiplier; the reported value is
    the max over computations. ``parallelism`` = total collectives / depth —
    the speedup an ideal parallel network could extract from this schedule.
    """
    comps = _split_computations(hlo)
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    entry = m.group(1) if m else next(iter(comps), "main")
    mult = _call_multipliers(comps, entry)

    total = 0.0
    worst = 0.0
    for cname, body in comps.items():
        depth: Dict[str, float] = {}
        comp_max = 0.0
        n_coll = 0
        for line in body.splitlines():
            mo = _INSTR_RE.match(line)
            if not mo:
                continue
            name, op, operands = mo.groups()
            is_coll = any(op.startswith(k) for k in _COLLECTIVES)
            d = 0.0
            for om in _OPERAND_RE.finditer(operands):
                d = max(d, depth.get(om.group(1), 0.0))
            # attrs after the operand list may also reference values (e.g.
            # tuple elements) — conservative: operands only.
            if is_coll and not op.endswith("-done"):
                d += 1.0
                n_coll += 1
            depth[name] = d
            comp_max = max(comp_max, d)
        k = mult.get(cname, 1)
        total += n_coll * k
        worst = max(worst, comp_max * k)
    return {"collective_count": total, "critical_depth": worst,
            "parallelism": (total / worst) if worst else 1.0}


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for op in ops:
        d = out.setdefault(op.kind, {"count": 0, "link_bytes": 0.0})
        d["count"] += op.multiplier
        d["link_bytes"] += op.link_bytes
    return out


# ---------------------------------------------------------------------------
# analytic workload model
# ---------------------------------------------------------------------------

def _attn_flops_fwd(cfg: ModelConfig, batch: int, seq: int,
                    kv_len: Optional[int] = None) -> float:
    if cfg.num_heads == 0:
        return 0.0
    kv_len = seq if kv_len is None else kv_len
    eff = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    if kv_len == seq and seq > 1:
        eff_avg = eff / 2 if cfg.sliding_window is None else (
            eff * (1 - eff / (2 * max(seq, 1))))  # causal and/or banded
    else:
        eff_avg = eff
    n_layers = (cfg.num_layers if cfg.family != "hybrid"
                else cfg.num_layers // cfg.hybrid_attn_every)
    # QK^T + PV
    return 4.0 * batch * seq * eff_avg * cfg.num_heads * cfg.head_dim * n_layers


def _ssd_flops_fwd(cfg: ModelConfig, batch: int, seq: int) -> float:
    if cfg.ssm is None:
        return 0.0
    c = cfg.ssm
    h = c.num_heads(cfg.d_model)
    n, p, ch = c.d_state, c.head_dim, c.chunk_size
    if seq == 1:
        return batch * h * (4.0 * n * p)  # recurrent step
    # per token: CB row (c*n), W@x (c*p), state in/out (2*n*p/c * c)
    per_tok = 2.0 * ch * n + 2.0 * ch * p + 4.0 * n * p
    return batch * seq * h * per_tok * cfg.num_layers


def analytic_flops(cfg: ModelConfig, shape: InputShape) -> Dict[str, float]:
    b, s = shape.global_batch, shape.seq_len
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = b * s
        matmul = 6.0 * n_active * tokens            # fwd(2) + bwd(4)
        attn = 3.0 * _attn_flops_fwd(cfg, b, s)
        ssd = 3.0 * _ssd_flops_fwd(cfg, b, s)
        # remat="dots" (selective recomputation) saves matmul outputs: the
        # re-forward repeats only cheap elementwise ops — no matmul FLOPs.
        no_refwd = cfg.remat in ("none", "dots")
        remat = 1.0 if no_refwd else (
            2.0 * n_active * tokens + _attn_flops_fwd(cfg, b, s)
            + _ssd_flops_fwd(cfg, b, s))            # re-run fwd
        total = matmul + attn + ssd + (0.0 if no_refwd else remat)
        model = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = b * s
        total = 2.0 * n_active * tokens + _attn_flops_fwd(cfg, b, s) \
            + _ssd_flops_fwd(cfg, b, s)
        model = 2.0 * n_active * tokens
    else:  # decode: one token against a seq_len cache
        tokens = b
        total = 2.0 * n_active * tokens \
            + _attn_flops_fwd(cfg, b, 1, kv_len=s) + _ssd_flops_fwd(cfg, b, 1)
        model = 2.0 * n_active * tokens
    return {"total": total, "model": model}


def analytic_hbm_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    """First-order HBM traffic model (per step,全 global)."""
    b, s = shape.global_batch, shape.seq_len
    pb = {"bfloat16": 2, "float32": 4}[cfg.param_dtype]
    ob = {"bfloat16": 2, "float32": 4}[cfg.optimizer_dtype]
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    d = cfg.d_model
    act_b = 2  # bf16 activations
    if shape.kind == "train":
        # weights: fwd read + bwd read + grad write; opt: m,v read+write, p write
        w = n_total * (3 * pb + 4 * ob + pb)
        # activations: residual stream + block internals, written+read once
        # (remat recomputes instead of storing internals -> factor ~8 d_model)
        acts = b * s * d * cfg.num_layers * act_b * 8
        return w + acts
    if shape.kind == "prefill":
        w = n_total * pb
        acts = b * s * d * cfg.num_layers * act_b * 4
        kv = (0 if cfg.num_heads == 0 else
              b * s * cfg.kv_dim * 2 * act_b * _attn_layers(cfg))
        return w + acts + kv
    # decode: every ACTIVE weight read once; KV cache read; states
    w = n_active * pb
    eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
    kv_b = 1 if "kv_fp8" in cfg.opts else act_b  # OPT(kv_fp8): 1-byte cache
    kv = (0 if cfg.num_heads == 0 else
          b * eff * cfg.kv_dim * max(1, cfg.decode_kv_expand)
          * 2 * kv_b * _attn_layers(cfg))
    ssm = 0.0
    if cfg.ssm is not None:
        c = cfg.ssm
        h = c.num_heads(cfg.d_model)
        ssm = b * h * c.d_state * c.head_dim * 4 * 2 * cfg.num_layers
    return w + kv + ssm


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.num_heads == 0:
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_attn_every
    return cfg.num_layers


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_total: float
    flops_model: float
    hbm_bytes: float
    link_bytes_per_chip: float
    hlo_flops_raw: Optional[float]
    collectives: Dict[str, Dict[str, float]]
    memory_per_chip: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.flops_total / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def model_ratio(self) -> float:
        return self.flops_model / max(self.flops_total, 1.0)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "flops_total": self.flops_total, "flops_model": self.flops_model,
            "model_ratio": self.model_ratio,
            "hbm_bytes": self.hbm_bytes,
            "link_bytes_per_chip": self.link_bytes_per_chip,
            "hlo_flops_raw": self.hlo_flops_raw,
            "collectives": self.collectives,
            "memory_per_chip": self.memory_per_chip,
        }


def build_roofline(cfg: ModelConfig, shape: InputShape, mesh_name: str,
                   chips: int, hlo_text: str,
                   cost: Optional[dict], mem: Optional[dict]) -> Roofline:
    ops = parse_collectives(hlo_text, chips)
    summ = collective_summary(ops)
    summ["_structure"] = collective_critical_depth(hlo_text)
    link_per_chip = sum(d["link_bytes"] for d in summ.values()
                        if "link_bytes" in d)
    fl = analytic_flops(cfg, shape)
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_total=fl["total"], flops_model=fl["model"],
        hbm_bytes=analytic_hbm_bytes(cfg, shape),
        link_bytes_per_chip=link_per_chip,
        hlo_flops_raw=(cost or {}).get("flops"),
        collectives=summ,
        memory_per_chip=mem,
    )
