"""Blockwise flash attention — Pallas TPU kernel.

TPU-native adaptation: (Bq, hd) query tiles live in VMEM; the kernel walks
KV blocks along the innermost ("arbitrary") grid dimension, keeping the
online-softmax running max/denominator and the output accumulator in VMEM
scratch across iterations. MXU-aligned block shapes (multiples of 128 on the
matmul dims) are chosen by ``repro.kernels.ops.flash_attention``.

Supports causal masking, sliding windows (SWA) and GQA (the KV index map
folds the query head onto its KV group), with block-level early-out for
fully-masked tiles.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, seq_k: int,
            causal: bool, window: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level mask decision (static per grid step at trace time is not
    # possible — q_start/k_start are dynamic — so use pl.when on scalars)
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window is not None:
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                # (Bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (Bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                # (Bk, hd)
        # zero padded KV rows: padding memory is unspecified, and 0 * NaN
        # would poison the accumulator even under a fully-masked p.
        kv_valid = (k_start + jax.lax.broadcasted_iota(
            jnp.int32, (v.shape[0], 1), 0)) < seq_k
        v = jnp.where(kv_valid, v, 0.0)
        k = jnp.where(kv_valid, k, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kp < seq_k
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)          # (Bq,1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    rep = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, seq_k=sk,
        causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
