"""Table-driven row gather — Pallas TPU (scalar prefetch).

The MoE dispatch/combine hot path: move token rows into expert-capacity
buffers (and back) according to a routing table computed on the host side of
the matmuls. On GPU this is a hand-rolled scatter kernel; the TPU-native
version uses Pallas *scalar prefetch* — the routing table is prefetched to
SMEM and consumed by the BlockSpec ``index_map``, so each grid step DMAs the
right source row tile directly (the pattern paged-attention kernels use).

``idx[i] < 0`` marks an invalid row (capacity padding): the output tile is
zero-filled.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, src_ref, out_ref):
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    out_ref[...] = jnp.where(valid, src_ref[...], 0.0).astype(out_ref.dtype)


def row_gather_pallas(src, idx, *, block_d: int = 512,
                      interpret: bool = False) -> jax.Array:
    """out[i, :] = src[idx[i], :] (0 where idx[i] < 0).

    src: (T, d); idx: (M,) int32 -> out: (M, d)
    """
    t, d = src.shape
    m = idx.shape[0]
    block_d = min(block_d, d)
    nd = pl.cdiv(d, block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, nd),
        in_specs=[
            pl.BlockSpec((1, block_d),
                         lambda i, j, idx_ref: (jnp.maximum(idx_ref[i], 0), j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, idx_ref: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), src.dtype),
        interpret=interpret,
    )(idx, src)


def row_gather_ref(src, idx) -> jax.Array:
    safe = jnp.maximum(idx, 0)
    out = src[safe]
    return jnp.where((idx >= 0)[:, None], out, 0.0).astype(src.dtype)
