"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: compiled Pallas on TPU backends, interpret
mode elsewhere (this container is CPU-only, so tests exercise interpret
mode; the kernels are TPU-target artifacts).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bucket_pack import (
    arena_from_leaves,
    bucket_pack_pallas,
    bucket_pack_ref,
    build_tile_tables,
)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gather import row_gather_pallas, row_gather_ref
from repro.kernels.ssd_scan import ssd_chunk_pallas


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """q: (B,H,Sq,hd); k/v: (B,KV,Skv,hd) -> (B,H,Sq,hd)."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, A, B, C, *, chunk: int, interpret: Optional[bool] = None):
    """Full blocked SSD using the Pallas intra-chunk kernel + jnp inter-chunk
    associative scan. Same contract as ``repro.models.ssm.ssd_chunked`` with
    no initial state. x: (b,s,h,p); dt: (b,s,h); A: (h,); B/C: (b,s,g,n)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    f32 = jnp.float32

    dA = dt.astype(f32) * A.astype(f32)
    cum = dA.reshape(b, nc, chunk, h).cumsum(axis=2)

    # flatten (b, h) -> bh for the kernel grid, broadcasting B/C to heads
    def flat(t):  # (b, nc, c, h, ...) -> (b*h, nc, c, ...)
        perm = (0, 3, 1, 2) + tuple(range(4, t.ndim))
        t = t.transpose(perm)
        return t.reshape((b * h,) + t.shape[2:])

    xs = flat(x.reshape(b, nc, chunk, h, p))
    dts = flat(dt.astype(f32).reshape(b, nc, chunk, h))
    cums = flat(cum.transpose(0, 1, 2, 3))  # (b,nc,c,h) -> flat
    Bh = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Ch = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)
    Bs, Cs = flat(Bh), flat(Ch)

    y_intra, st_loc = ssd_chunk_pallas(xs, dts, cums, Bs, Cs,
                                       interpret=_auto_interpret(interpret))

    # inter-chunk associative scan (jnp — O(nc * n * p), negligible)
    a = jnp.exp(cums[:, :, -1, None, None])                    # (bh,nc,1,1)

    def op(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2 * s1 + s2

    _, acc = jax.lax.associative_scan(op, (a, st_loc), axis=1)
    s_prev = jnp.concatenate(
        [jnp.zeros_like(acc[:, :1]), acc[:, :-1]], axis=1)     # (bh,nc,n,p)
    final_state = acc[:, -1]

    decay_in = jnp.exp(cums)                                   # (bh,nc,c)
    y_inter = jnp.einsum("zncq,znqp,znc->zncp", Cs, s_prev, decay_in)

    y = (y_intra + y_inter).reshape(b, h, nc * chunk, p).transpose(0, 2, 1, 3)
    fs = final_state.reshape(b, h, n, p)
    return y.astype(x.dtype), fs


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def row_gather(src, idx, *, block_d: int = 512,
               interpret: Optional[bool] = None):
    """out[i] = src[idx[i]] (zeros where idx < 0)."""
    return row_gather_pallas(src, idx, block_d=block_d,
                             interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("padded_size", "tile", "interpret"))
def bucket_pack(src, block, valid, *, padded_size: int, tile: int = 1024,
                interpret: Optional[bool] = None):
    """Pack tile-aligned gradient segments into one flat send buffer."""
    return bucket_pack_pallas(src, block, valid, padded_size, tile=tile,
                              interpret=_auto_interpret(interpret))


__all__ = ["arena_from_leaves", "bucket_pack", "bucket_pack_ref",
           "build_tile_tables", "flash_attention", "row_gather",
           "row_gather_ref", "ssd_chunked"]
