"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel sweeps in ``tests/test_kernels.py``
assert against (``interpret=True`` execution of the kernels on CPU).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B,H,Sq,hd); k/v: (B,KV,Skv,hd). GQA via head broadcast."""
    b, h, sq, hd = q.shape
    kv = k.shape[1]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD intra-chunk oracle
# ---------------------------------------------------------------------------

def ssd_chunk_ref(x, dt, cum, B, C) -> Tuple[jax.Array, jax.Array]:
    """One chunk, one head.

    x: (c, p); dt: (c,); cum: (c,) cumulative dA; B, C: (c, n)
    returns (y_intra: (c, p), state: (n, p))
    """
    c = x.shape[0]
    f32 = jnp.float32
    x, dt, cum, B, C = (t.astype(f32) for t in (x, dt, cum, B, C))
    L = jnp.exp(cum[:, None] - cum[None, :])
    L = jnp.where(jnp.tril(jnp.ones((c, c), bool)), L, 0.0)
    W = (C @ B.T) * L * dt[None, :]
    y = W @ x
    decay_end = jnp.exp(cum[-1] - cum)
    state = (B * (dt * decay_end)[:, None]).T @ x          # (n, p)
    return y, state


def ssd_chunk_batched_ref(x, dt, cum, B, C):
    """x: (bh, nc, c, p); dt/cum: (bh, nc, c); B/C: (bh, nc, c, n)."""
    f = jax.vmap(jax.vmap(ssd_chunk_ref))
    return f(x, dt, cum, B, C)


# ---------------------------------------------------------------------------
# bucket pack oracle
# ---------------------------------------------------------------------------

def pack_ref(src: jax.Array, src_off: np.ndarray, dst_off: np.ndarray,
             sizes: np.ndarray, dst_size: int) -> jax.Array:
    """Copy ``len(sizes)`` segments from a flat source arena into an aligned
    destination buffer (zeros elsewhere)."""
    dst = jnp.zeros((dst_size,), src.dtype)
    for so, do, n in zip(src_off, dst_off, sizes):
        dst = jax.lax.dynamic_update_slice(
            dst, jax.lax.dynamic_slice(src, (int(so),), (int(n),)), (int(do),))
    return dst
