"""Mamba2 SSD intra-chunk kernel — Pallas TPU.

The blocked SSD algorithm (models/ssm.py) splits into a quadratic
*intra-chunk* part (MXU-friendly: three (c x c)/(c x n)/(c x p) matmuls per
chunk) and a cheap inter-chunk associative scan. This kernel computes the
intra-chunk part — per (batch*head, chunk) grid step it keeps the whole
working set (x, B, C tiles plus the (c x c) decay matrix) in VMEM, which is
exactly the materialization the pure-XLA path spills to HBM.

chunk=256, n<=128, p=64 => VMEM footprint ≈ (256² + 3·256·128) f32 ≈ 650 KB.

The inter-chunk recurrence stays in jnp (``ops.ssd_chunked``): it is
O(S/c · n · p) — negligible — and XLA's associative scan handles it well.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params


def _kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref, *, chunk: int):
    f32 = jnp.float32
    x = x_ref[0, 0].astype(f32)          # (c, p)
    dt = dt_ref[0, 0].astype(f32)        # (c, 1)
    cum = cum_ref[0, 0].astype(f32)      # (c, 1)
    B = b_ref[0, 0].astype(f32)          # (c, n)
    C = c_ref[0, 0].astype(f32)          # (c, n)

    # decay L[i,j] = exp(cum_i - cum_j), lower-triangular
    diff = cum - cum.reshape(1, chunk)                       # (c, c)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)     # (c, c)
    W = CB * L * dt.reshape(1, chunk)
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)      # (c, p)

    decay_end = jnp.exp(cum[chunk - 1, 0] - cum)             # (c, 1)
    Bw = B * (dt * decay_end)                                # (c, n)
    st = jax.lax.dot_general(Bw, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=f32)     # (n, p)

    y_ref[0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0] = st.astype(st_ref.dtype)


def ssd_chunk_pallas(x, dt, cum, B, C, *, interpret: bool = False):
    """Intra-chunk SSD over all (batch*head, chunk) pairs.

    x:   (bh, nc, c, p)
    dt:  (bh, nc, c)      positive step sizes
    cum: (bh, nc, c)      cumulative dA within the chunk
    B,C: (bh, nc, c, n)
    returns (y_intra: (bh, nc, c, p) f32, state: (bh, nc, n, p) f32)
    """
    bh, nc, c, p = x.shape
    n = B.shape[-1]
    dt2 = dt[..., None]
    cum2 = cum[..., None]
    kernel = functools.partial(_kernel, chunk=c)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, c, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, nc, n, p), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, dt2, cum2, B, C)
