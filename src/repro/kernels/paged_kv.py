"""Paged KV-cache page gather — Pallas TPU (scalar prefetch).

The paged serve cache stores K/V in a fixed pool of fixed-size pages
(``(num_pages, page_size, KV, hd)`` per layer) with a per-slot page table;
attention needs each slot's pages laid out contiguously in sequence order.
This is the same shape of problem as the gradient-bucket pack
(`repro.kernels.bucket_pack`): a table-driven tile gather whose index
tables are known outside the kernel. The TPU kernel DMAs one pool page per
grid step straight to its destination row, driven by the prefetched page
table — unmapped entries (``-1``, pad prefix / freed slots) emit zeros.

Three equivalent implementations, mirroring the bucket-pack layering:

* :func:`paged_gather_pallas` — the TPU scalar-prefetch kernel
  (interpret-mode tested on CPU);
* :func:`paged_gather_take`   — the vectorized ``jnp.take`` lowering used
  on backends without a Pallas TPU pipeline (XLA:CPU scalarizes nothing
  here — it is one gather);
* :func:`paged_gather_ref`    — scalar oracle for the kernel tests.

:func:`paged_gather` dispatches on the backend; the model code calls only
this entry point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(table_ref, pool_ref, out_ref):
    t = pl.program_id(0)
    mapped = table_ref[t] >= 0
    out_ref[...] = jnp.where(mapped, pool_ref[...],
                             jnp.zeros_like(pool_ref[...]))


def paged_gather_pallas(pool: jax.Array, table: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """pool: (NP, PS, KV, hd) one layer's page pool; table: (B, MAXP) int32
    pool page ids (-1 unmapped). Returns (B, MAXP*PS, KV, hd) — slot b's
    pages in logical order, unmapped pages zero-filled.

    Grid = one destination page per step; the BlockSpec index_map consumes
    the prefetched (flattened) table so each step DMAs exactly one pool
    page (clamped to 0 for unmapped entries, zeroed in the kernel body).
    """
    b, maxp = table.shape
    np_, ps = pool.shape[0], pool.shape[1]
    tail = pool.shape[2:]
    flat_table = table.reshape(-1)
    pool2 = pool.reshape(np_, ps, -1)
    e = pool2.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * maxp,),
        in_specs=[
            pl.BlockSpec((1, ps, e),
                         lambda t, table_ref: (jnp.maximum(table_ref[t], 0),
                                               0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ps, e), lambda t, table_ref: (t, 0, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * maxp, ps, e), pool.dtype),
        interpret=interpret,
    )(flat_table, pool2)
    return out.reshape((b, maxp * ps) + tail)


def paged_gather_take(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Vectorized lowering: ONE row gather of the pool's pages plus an
    unmapped-page mask — numerically identical to the kernel."""
    b, maxp = table.shape
    ps = pool.shape[1]
    pages = jnp.take(pool, jnp.clip(table, 0, pool.shape[0] - 1), axis=0)
    mapped = (table >= 0).reshape(b, maxp, 1, 1, 1)
    pages = jnp.where(mapped, pages, jnp.zeros((), pool.dtype))
    return pages.reshape((b, maxp * ps) + pool.shape[2:])


def paged_gather_ref(pool, table) -> jax.Array:
    """Scalar jnp oracle for the interpret-mode kernel tests."""
    b, maxp = table.shape
    ps = pool.shape[1]
    rows = []
    for i in range(b):
        pages = []
        for p in range(maxp):
            pid = int(table[i, p])
            pages.append(pool[pid] if pid >= 0
                         else jnp.zeros_like(pool[0]))
        rows.append(jnp.concatenate(pages, axis=0))
    return jnp.stack(rows).reshape((b, maxp * ps) + pool.shape[2:])


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_gather(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Backend dispatch: Pallas tile-gather on TPU, one-gather jnp.take
    lowering elsewhere (the CPU smoke/conformance path)."""
    if _on_tpu():
        return paged_gather_pallas(pool, table)
    return paged_gather_take(pool, table)
