"""Gradient-bucket packing — Pallas TPU (scalar prefetch).

The paper's per-VCI request cache keeps each stream's staging memory
private; the training-loop analogue packs a bucket's gradient shards into
one flat, tile-aligned send buffer before the bucketed all-reduce
(`repro.core.bucketing.pack_bucket` is the XLA path built from
concatenates). For many small leaves the XLA path materializes one copy
per concat operand; this kernel instead DMAs each destination tile
straight from its source segment, driven by prefetched index tables (the
same scalar-prefetch pattern as `moe_gather`).

Layout contract: segments (leaf flats) sit at TILE-ALIGNED offsets in
both the source arena and the destination buffer — the alignment the
paper's "cache-line aware VCI" optimization prescribes (§4.3) and that
``plan_buckets(align=TILE)`` produces. A destination tile therefore maps
to exactly one source segment; tail tiles zero-fill past ``valid``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 8 * 128


def build_tile_tables(src_off, dst_off, sizes, padded_size: int,
                      tile: int = TILE) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side: per-destination-tile (source block index, valid count).

    ``src_off``/``dst_off`` must be tile-aligned (see module docstring).
    Returns (block: int32[n_tiles], valid: int32[n_tiles]).
    """
    assert padded_size % tile == 0
    src_off = np.asarray(src_off)
    dst_off = np.asarray(dst_off)
    sizes = np.asarray(sizes)
    assert (src_off % tile == 0).all(), "source segments must be tile-aligned"
    assert (dst_off % tile == 0).all(), "dest segments must be tile-aligned"
    n_tiles = padded_size // tile
    block = np.zeros((n_tiles,), np.int32)
    valid = np.zeros((n_tiles,), np.int32)
    order = np.argsort(dst_off)
    for i in order:
        n_seg_tiles = -(-int(sizes[i]) // tile)
        t0 = int(dst_off[i]) // tile
        for k in range(n_seg_tiles):
            block[t0 + k] = int(src_off[i]) // tile + k
            valid[t0 + k] = min(tile, int(sizes[i]) - k * tile)
    return block, valid


def _kernel(block_ref, valid_ref, src_ref, out_ref, *, tile: int):
    t = pl.program_id(0)
    v = valid_ref[t]
    idx = jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    out_ref[...] = jnp.where(idx < v, src_ref[...], 0.0).astype(out_ref.dtype)


def bucket_pack_pallas(src: jax.Array, block: jax.Array, valid: jax.Array,
                       padded_size: int, *, tile: int = TILE,
                       interpret: bool = False) -> jax.Array:
    """src: flat tile-aligned arena; returns the (padded_size,) packed
    buffer. ``block``/``valid`` from :func:`build_tile_tables`; the
    BlockSpec index_map consumes the prefetched ``block`` table so each
    grid step DMAs exactly one source tile."""
    assert padded_size % tile == 0
    assert src.shape[0] % tile == 0
    n_tiles = padded_size // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,),
                         lambda t, block_ref, valid_ref: (block_ref[t],)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, b, v: (t,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((padded_size,), src.dtype),
        interpret=interpret,
    )(block, valid, src)


def bucket_pack_ref(src, block, valid, padded_size: int,
                    tile: int = TILE) -> jax.Array:
    """Pure-jnp oracle."""
    n_tiles = padded_size // tile
    out = jnp.zeros((padded_size,), src.dtype)
    for t in range(n_tiles):
        b = int(block[t])
        v = int(valid[t])
        seg = jax.lax.dynamic_slice(src, (b * tile,), (tile,))
        idx = jnp.arange(tile)
        seg = jnp.where(idx < v, seg, 0.0)
        out = jax.lax.dynamic_update_slice(out, seg.astype(src.dtype),
                                           (t * tile,))
    return out


def arena_from_leaves(leaves, tile: int = TILE):
    """Lay leaves into a tile-aligned flat arena; returns (arena, offsets)."""
    offs = []
    parts = []
    cur = 0
    for leaf in leaves:
        flat = jnp.ravel(leaf)
        offs.append(cur)
        pad = (-flat.shape[0]) % tile
        if pad:
            flat = jnp.pad(flat, (0, pad))
        parts.append(flat)
        cur += flat.shape[0]
    return jnp.concatenate(parts), np.array(offs, np.int32)
