"""Gradient-bucket pack/unpack — Pallas TPU (scalar prefetch).

The paper's per-VCI request cache keeps each stream's staging memory
private; the training-loop analogue packs a bucket's gradient shards into
one flat, tile-aligned send buffer before the bucketed all-reduce
(`repro.core.bucketing.pack_bucket` is the XLA path built from
concatenates). For many small leaves the XLA path materializes one copy
per concat operand; this kernel instead DMAs each destination tile
straight from its source segment, driven by prefetched index tables (the
same scalar-prefetch pattern as `moe_gather`).

Both directions of the fast path live here:

* :func:`bucket_pack_pallas`   — arena tiles -> one bucket's send buffer;
* :func:`bucket_unpack_pallas` — reduced bucket buffers -> arena tiles
  (the inverse DMA, same kernel body with the index tables swapped);
* :func:`bucket_pack_gather` / :func:`bucket_unpack_gather` — the exact
  vectorized-jnp lowering of the same tile-gather (one row gather + tail
  mask); reference semantics on backends without a Pallas TPU pipeline.
  (XLA:CPU scalarizes gathers, so ``reduce_gradients`` lowers the pack on
  non-TPU backends to per-slot dynamic_update_slice DMA writes instead —
  same layout contract, same bytes; see ``repro.core.bucketing``.)
* :func:`bucket_pack_ref` / :func:`bucket_unpack_ref` — scalar jnp oracles
  for the interpret-mode kernel tests.

Layout contract: segments (leaf flats) sit at TILE-ALIGNED offsets in
both the source arena and the destination buffer — the alignment the
paper's "cache-line aware VCI" optimization prescribes (§4.3) and that
``plan_buckets(align=TILE, slot_align=TILE)`` produces. A destination tile
therefore maps to exactly one source segment; tail tiles zero-fill past
``valid``. Index tables are host-side numpy (:func:`build_tile_tables`,
:func:`arena_layout`) so a persistent ``CommPlan`` can precompute them once
per (treedef, shapes) and reuse them across steps and retraces.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 8 * 128


def build_tile_tables(src_off, dst_off, sizes, padded_size: int,
                      tile: int = TILE) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side: per-destination-tile (source block index, valid count).

    ``src_off``/``dst_off`` must be tile-aligned (see module docstring).
    Returns (block: int32[n_tiles], valid: int32[n_tiles]).
    """
    assert padded_size % tile == 0
    src_off = np.asarray(src_off)
    dst_off = np.asarray(dst_off)
    sizes = np.asarray(sizes)
    assert (src_off % tile == 0).all(), "source segments must be tile-aligned"
    assert (dst_off % tile == 0).all(), "dest segments must be tile-aligned"
    n_tiles = padded_size // tile
    block = np.zeros((n_tiles,), np.int32)
    valid = np.zeros((n_tiles,), np.int32)
    order = np.argsort(dst_off)
    for i in order:
        n_seg_tiles = -(-int(sizes[i]) // tile)
        t0 = int(dst_off[i]) // tile
        for k in range(n_seg_tiles):
            block[t0 + k] = int(src_off[i]) // tile + k
            valid[t0 + k] = min(tile, int(sizes[i]) - k * tile)
    return block, valid


def _kernel(block_ref, valid_ref, src_ref, out_ref, *, tile: int):
    t = pl.program_id(0)
    v = valid_ref[t]
    idx = jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    out_ref[...] = jnp.where(idx < v, src_ref[...], 0.0).astype(out_ref.dtype)


def bucket_pack_pallas(src: jax.Array, block: jax.Array, valid: jax.Array,
                       padded_size: int, *, tile: int = TILE,
                       interpret: bool = False) -> jax.Array:
    """src: flat tile-aligned arena; returns the (padded_size,) packed
    buffer. ``block``/``valid`` from :func:`build_tile_tables`; the
    BlockSpec index_map consumes the prefetched ``block`` table so each
    grid step DMAs exactly one source tile."""
    assert padded_size % tile == 0
    assert src.shape[0] % tile == 0
    n_tiles = padded_size // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,),
                         lambda t, block_ref, valid_ref: (block_ref[t],)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, b, v: (t,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((padded_size,), src.dtype),
        interpret=interpret,
    )(block, valid, src)


def bucket_unpack_pallas(packed: jax.Array, block: jax.Array,
                         valid: jax.Array, out_size: int, *,
                         tile: int = TILE,
                         interpret: bool = False) -> jax.Array:
    """Inverse DMA: gather ``packed``'s tiles back into arena layout.

    ``packed`` is the (concatenated) reduced bucket buffer(s); ``block``
    maps each destination (arena) tile to its source tile inside
    ``packed``; ``valid`` zero-fills each tile's tail past the segment end.
    Same kernel body as the pack direction — only the host-built index
    tables differ (:func:`build_tile_tables` with src/dst roles swapped).
    """
    return bucket_pack_pallas(packed, block, valid, out_size, tile=tile,
                              interpret=interpret)


def bucket_pack_ref(src, block, valid, padded_size: int,
                    tile: int = TILE) -> jax.Array:
    """Pure-jnp oracle."""
    n_tiles = padded_size // tile
    out = jnp.zeros((padded_size,), src.dtype)
    for t in range(n_tiles):
        b = int(block[t])
        v = int(valid[t])
        seg = jax.lax.dynamic_slice(src, (b * tile,), (tile,))
        idx = jnp.arange(tile)
        seg = jnp.where(idx < v, seg, 0.0)
        out = jax.lax.dynamic_update_slice(out, seg.astype(src.dtype),
                                           (t * tile,))
    return out


def bucket_unpack_ref(packed, block, valid, out_size: int,
                      tile: int = TILE) -> jax.Array:
    """Pure-jnp oracle for the unpack direction (same gather semantics)."""
    return bucket_pack_ref(packed, block, valid, out_size, tile=tile)


def bucket_pack_gather(src: jax.Array, block, valid, padded_size: int,
                       tile: int = TILE) -> jax.Array:
    """Vectorized jnp lowering of the pack kernel for non-TPU backends:
    ONE row-gather of the source's tiles plus a tail mask — numerically
    identical to :func:`bucket_pack_pallas`, but a 2-op XLA program
    instead of a Python-stepped interpret-mode grid."""
    assert padded_size % tile == 0 and src.shape[0] % tile == 0
    block = jnp.asarray(block, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    tiles = src.reshape(-1, tile)[block]                  # (n_tiles, tile)
    lane = jnp.arange(tile, dtype=jnp.int32)[None, :]
    tiles = jnp.where(lane < valid[:, None], tiles, 0).astype(src.dtype)
    return tiles.reshape(padded_size)


def bucket_unpack_gather(packed: jax.Array, block, valid, out_size: int,
                         tile: int = TILE) -> jax.Array:
    """Vectorized jnp lowering of the unpack direction."""
    return bucket_pack_gather(packed, block, valid, out_size, tile=tile)


def arena_layout(sizes, tile: int = TILE) -> Tuple[np.ndarray, int]:
    """Host-side arena layout: each leaf (by flat ``sizes``) at the next
    tile-aligned offset. Returns (offsets: int64[n], total arena size)."""
    offs = np.zeros((len(sizes),), np.int64)
    cur = 0
    for i, sz in enumerate(sizes):
        offs[i] = cur
        cur += -(-int(sz) // tile) * tile
    return offs, max(int(cur), tile)


def arena_from_leaves(leaves, tile: int = TILE, dtype=None):
    """Lay leaves into a tile-aligned flat arena; returns (arena, offsets)."""
    offs = []
    parts = []
    cur = 0
    for leaf in leaves:
        flat = jnp.ravel(leaf)
        if dtype is not None:
            flat = flat.astype(dtype)
        offs.append(cur)
        pad = (-flat.shape[0]) % tile
        if pad:
            flat = jnp.pad(flat, (0, pad))
        parts.append(flat)
        cur += flat.shape[0]
    return jnp.concatenate(parts), np.array(offs, np.int64)
