"""Sharding rules: TP(model) x FSDP(data/pod) parameter layout + activation
constraints.

One rule table drives three consumers:

* :func:`param_specs` — a ``PartitionSpec`` tree that mirrors a config's
  parameter tree exactly (used by ``launch/inputs.py`` to build
  ``NamedSharding`` trees for the dry-run and by ``tests/test_sharding.py``);
* :meth:`Sharder.materialize` — the ZeRO/FSDP weight gather: inside the
  traced step each layer's weights are constrained to their TP-only spec
  (FSDP axes dropped), so XLA inserts the all-gather right before use;
* the activation constraint helpers (``hidden`` / ``heads`` / ``kv_cache`` /
  ``ffn_hidden`` / ``logits`` / ``act``) used throughout the model code.

Every axis assignment is divisibility-guarded: an axis (or axis tuple) is
attached to a tensor dim only when the dim divides the axis product, so the
same rules hold on any mesh (16x16, 2x16x16, 1-D CPU test meshes, or the
duck-typed fake meshes the sharding tests use).

Axis convention: ``model`` is the tensor-parallel axis; every other mesh
axis (``data``, ``pod``) is data-parallel — :func:`batch_axes` returns them
in mesh order.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

AxisLike = Union[None, str, Tuple[str, ...]]

# model goes on the LAST dim (column-parallel) for these weight names, on
# dim -2 (row-parallel) for the _TP_ROW names; biases follow their matmul.
_TP_COL = frozenset({"wq", "wk", "wv", "w_gate", "w_up", "in_proj"})
_TP_ROW = frozenset({"wo", "w_down", "out_proj"})
_TP_BIAS = frozenset({"bq", "bk", "bv", "b_up"})


# ---------------------------------------------------------------------------
# mesh introspection (works on real Mesh, duck-typed fakes, and None)
# ---------------------------------------------------------------------------

def batch_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel mesh axes, in mesh order (everything but model)."""
    if mesh is None:
        return ("data",)
    return tuple(a for a in mesh.axis_names if a != "model")


def data_axes(mesh, cfg: Optional[ModelConfig] = None) -> Tuple[str, ...]:
    """Axes the batch dimension shards over (cfg hook for future overrides)."""
    return batch_axes(mesh)


def zero1_opt_specs(mesh, opt_state):
    """PartitionSpec tree for a ZeRO-1 optimizer state (flat bucket space).

    Every 1-D leaf is a per-bucket flat buffer (m / v / fp32 master) owned
    1/N across the data axes — spec'd ``P(data...)`` on its only dim so the
    global array is STORED sharded and each rank's ``shard_map`` view is
    exactly its :class:`~repro.core.bucketing.ShardLayout` shard. Scalars
    (the step count) replicate. Works on concrete states and on
    ``jax.eval_shape`` structs alike.
    """
    dp = batch_axes(mesh)
    dpe = dp_entry(dp)
    return jax.tree_util.tree_map(
        lambda l: P(dpe) if getattr(l, "ndim", 0) == 1 else P(), opt_state)


def _axis_size(mesh, ax: AxisLike) -> int:
    if mesh is None or ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= dict(mesh.shape).get(a, 1)
    return n


def dp_entry(dp: Tuple[str, ...]) -> AxisLike:
    """A PartitionSpec entry sharding one dim over ALL the data axes: the
    bare axis name for a 1-axis mesh, the tuple for data x pod meshes.
    Shared by the ZeRO-1 opt-state specs, the trainer's decay-mask specs,
    and the overlap scheduler's shard taps."""
    return dp[0] if len(dp) == 1 else tuple(dp)



# ---------------------------------------------------------------------------
# the parameter rule table
# ---------------------------------------------------------------------------

def _leaf_spec(mesh, keys: Sequence[str], shape: Tuple[int, ...], *,
               stacked: bool, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf, selected by its tree path.

    ``stacked`` marks a leading layer-stack dim (always unsharded).
    ``fsdp=False`` drops the data-axis weight sharding (TP-only spec) — the
    materialize/ZeRO-gather view. Expert-parallel dims on MoE expert tables
    are kept either way (they are parallelism, not storage sharding).
    """
    nd = len(shape)
    spec: list = [None] * nd
    if nd == 0:
        return P()
    lead = 1 if stacked else 0
    dp = batch_axes(mesh)
    dpn = _axis_size(mesh, tuple(dp))
    tp = _axis_size(mesh, "model")
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""

    def model_ok(dim: int) -> bool:
        return tp > 1 and dim >= lead and shape[dim] % tp == 0

    def dp_ok(dim: int) -> bool:
        return fsdp and dpn > 1 and dim >= lead and shape[dim] % dpn == 0

    if parent == "moe" and name in ("w_gate", "w_up", "w_down"):
        # (..., E, a, b) expert tables: expert-parallel over the data axes
        # when E divides (arctic 128 % 16), else the E dim stays unsharded
        # (mixtral 8 on 16 — the FSDP fallback lands on d_model below).
        e_dim = lead
        if dpn > 1 and shape[e_dim] % dpn == 0:
            spec[e_dim] = dp_entry(dp)
        ff_dim = nd - 1 if name in ("w_gate", "w_up") else nd - 2
        if model_ok(ff_dim):
            spec[ff_dim] = "model"
        elif spec[e_dim] is None:
            d_dim = nd - 2 if name in ("w_gate", "w_up") else nd - 1
            if dp_ok(d_dim):
                spec[d_dim] = dp_entry(dp)
    elif name == "router":
        pass  # tiny, replicated
    elif parent == "embed" and nd >= 2:           # (V, d) or (K, V, d)
        if model_ok(nd - 2):
            spec[nd - 2] = "model"                # vocab column-parallel
        if dp_ok(nd - 1):
            spec[nd - 1] = dp_entry(dp)
    elif parent in ("lm_head", "img_proj") and nd >= 2:
        if model_ok(nd - 1):
            spec[nd - 1] = "model"
        if dp_ok(nd - 2):
            spec[nd - 2] = dp_entry(dp)
    elif name in _TP_COL and nd >= 2:
        if model_ok(nd - 1):
            spec[nd - 1] = "model"
        if dp_ok(nd - 2):
            spec[nd - 2] = dp_entry(dp)
    elif name in _TP_ROW and nd >= 2:
        if model_ok(nd - 2):
            spec[nd - 2] = "model"
        if dp_ok(nd - 1):
            spec[nd - 1] = dp_entry(dp)
    elif name in _TP_BIAS:
        if model_ok(nd - 1):
            spec[nd - 1] = "model"
    # everything else (norm scales, conv_w, A_log, D, dt_bias, ...) replicates
    return P(*spec)


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "name", k))) for k in path)


def param_specs(cfg: ModelConfig, mesh):
    """A PartitionSpec tree with the exact structure of ``init_params(cfg)``."""
    from repro.models.transformer import init_params  # avoid import cycle

    struct = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), np.uint32))

    def assign(path, leaf):
        keys = _path_keys(path)
        stacked = bool(keys) and keys[0] == "layers"
        return _leaf_spec(mesh, keys, tuple(leaf.shape), stacked=stacked)

    return jax.tree_util.tree_map_with_path(assign, struct)


# ---------------------------------------------------------------------------
# the activation/weight constraint helper
# ---------------------------------------------------------------------------

class Sharder:
    """Sharding-constraint helper bound to one (mesh, config) pair.

    With ``mesh=None`` every method is the identity — the same model code
    runs unsharded in unit tests and sharded under the dry-run meshes.
    """

    def __init__(self, mesh: Optional[Mesh], cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.dp: Tuple[str, ...] = batch_axes(mesh)

    # -- mesh arithmetic -------------------------------------------------
    def _axsize(self, ax: AxisLike) -> int:
        return _axis_size(self.mesh, ax)

    def div(self, n: int, ax: AxisLike) -> bool:
        """True when ``n`` can shard over ``ax`` (present, >1, divides)."""
        sz = self._axsize(ax)
        return sz > 1 and n % sz == 0

    # -- raw constraint --------------------------------------------------
    def act(self, x, *axes: AxisLike):
        """Constrain ``x`` dim-by-dim; axes absent from the mesh drop out."""
        if self.mesh is None:
            return x
        clean = tuple(a if self._axsize(a) > 1 else None for a in axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*clean)))

    def _batch(self, n: int) -> AxisLike:
        return dp_entry(self.dp) if self.div(n, tuple(self.dp)) else None

    # -- named activation sites ------------------------------------------
    def hidden(self, x):
        """(B, S, d) residual-stream activations: batch over data axes."""
        return self.act(x, self._batch(x.shape[0]), *([None] * (x.ndim - 1)))

    def heads(self, q):
        """(B, S, H, hd): attention/SSM heads over model."""
        h_ax = "model" if self.div(q.shape[2], "model") else None
        return self.act(q, self._batch(q.shape[0]), None, h_ax, None)

    def kv_cache(self, k):
        """(B, S, KV, hd) stacked KV cache: KV heads over model when they
        divide (see ``decode_kv_expand``), else unsharded heads."""
        kv_ax = "model" if self.div(k.shape[2], "model") else None
        return self.act(k, self._batch(k.shape[0]), None, kv_ax, None)

    def ffn_hidden(self, h):
        """(B, S, d_ff): the TP'd FFN inner dim."""
        f_ax = "model" if self.div(h.shape[-1], "model") else None
        return self.act(h, self._batch(h.shape[0]),
                        *([None] * (h.ndim - 2)), f_ax)

    def logits(self, logits):
        """(B, S, V): vocab over model (column-parallel lm head)."""
        v_ax = "model" if self.div(logits.shape[-1], "model") else None
        return self.act(logits, self._batch(logits.shape[0]),
                        *([None] * (logits.ndim - 2)), v_ax)

    # -- weights ----------------------------------------------------------
    def materialize(self, p):
        """ZeRO/FSDP weight gather: constrain a (per-layer) param subtree to
        its TP-only spec, so the data-axis shards all-gather right before
        use and the gathered copy is freed after the layer."""
        if self.mesh is None:
            return p

        def assign(path, leaf):
            spec = _leaf_spec(self.mesh, _path_keys(path),
                              tuple(leaf.shape), stacked=False, fsdp=False)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(self.mesh, spec))

        return jax.tree_util.tree_map_with_path(assign, p)
