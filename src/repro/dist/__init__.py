"""repro.dist — mesh/axis bookkeeping and sharding rules.

Public API:
    Sharder                 — activation/weight sharding-constraint helper
    batch_axes, data_axes   — the mesh's data-parallel axes
    param_specs             — PartitionSpec tree mirroring a config's params
"""

from repro.dist.sharding import Sharder, batch_axes, data_axes, param_specs

__all__ = ["Sharder", "batch_axes", "data_axes", "param_specs"]
