"""Deterministic synthetic data pipeline.

Produces per-arch batches (text / VLM / audio) both as concrete arrays
(training, benchmarks) and as ``ShapeDtypeStruct`` specs (the dry-run).

The token stream is a *learnable* noisy successor process — token[t+1] =
(token[t] + stride) mod V with probability 1-noise — so integration tests
can assert that training reduces loss well below the uniform baseline.

Sharded placement: ``place_batch`` builds the global batch from per-shard
callbacks via ``jax.make_array_from_callback``, the multi-host-safe path
(each host materializes only its addressable shards).
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.transformer import IMG_EMBED_DIM

PAD_LABEL = -1


def _succ_tokens(rng: np.random.Generator, shape, vocab: int,
                 stride: int = 7, noise: float = 0.1) -> np.ndarray:
    """Noisy successor sequences along the last axis."""
    out = np.empty(shape, np.int32)
    first = rng.integers(0, vocab, shape[:-1])
    out[..., 0] = first
    for t in range(1, shape[-1]):
        nxt = (out[..., t - 1] + stride) % vocab
        flip = rng.random(shape[:-1]) < noise
        rnd = rng.integers(0, vocab, shape[:-1])
        out[..., t] = np.where(flip, rnd, nxt)
    return out


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, *,
                    seed: int = 0, step: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if cfg.modality == "audio":
        toks = _succ_tokens(rng, (batch, cfg.num_codebooks, seq + 1),
                            cfg.vocab_size)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    if cfg.modality == "vlm":
        s_txt = seq - cfg.num_patches
        assert s_txt > 1, "seq must exceed num_patches"
        toks = _succ_tokens(rng, (batch, s_txt + 1), cfg.vocab_size)
        img = rng.standard_normal(
            (batch, cfg.num_patches, IMG_EMBED_DIM)).astype(np.float32)
        # labels aligned to the FULL (image+text) sequence; image positions masked
        labels = np.full((batch, seq), PAD_LABEL, np.int32)
        labels[:, cfg.num_patches:] = toks[:, 1:]
        return {"tokens": toks[:, :-1], "labels": labels, "image_embeds": img}
    toks = _succ_tokens(rng, (batch, seq + 1), cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int, *,
                      seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        yield synthetic_batch(cfg, batch, seq, seed=seed, step=step)
        step += 1


# ---------------------------------------------------------------------------
# dry-run specs + sharded placement
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, shape: InputShape,
               mesh: Optional[Mesh] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs (weak-type-correct, shardable) for every model input."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        if cfg.modality == "audio":
            return {"tokens": jax.ShapeDtypeStruct((b, cfg.num_codebooks, 1), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    spec: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.modality == "audio":
        spec["tokens"] = jax.ShapeDtypeStruct((b, cfg.num_codebooks, s), i32)
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, cfg.num_codebooks, s), i32)
    elif cfg.modality == "vlm":
        spec["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.num_patches), i32)
        spec["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, IMG_EMBED_DIM), jnp.bfloat16)
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    else:
        spec["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return spec


def batch_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """NamedShardings for the batch dict: batch dim over (pod, data)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dpn = int(np.prod([mesh.shape[a] for a in dp]))

    def shard_for(st: jax.ShapeDtypeStruct):
        lead = dp if st.shape[0] % dpn == 0 and st.shape[0] >= dpn else None
        return NamedSharding(mesh, P(lead, *([None] * (len(st.shape) - 1))))

    return {k: shard_for(v) for k, v in batch_spec(cfg, shape, mesh).items()}


def place_batch(batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    """Multi-host-safe placement: each device shard is materialized by callback."""
    out = {}
    for k, v in batch.items():
        sh = shardings[k]
        out[k] = jax.make_array_from_callback(v.shape, sh, lambda i, v=v: v[i])
    return out
