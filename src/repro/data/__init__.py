from repro.data.pipeline import (
    PAD_LABEL,
    batch_shardings,
    batch_spec,
    place_batch,
    synthetic_batch,
    synthetic_batches,
)

__all__ = ["PAD_LABEL", "batch_shardings", "batch_spec", "place_batch",
           "synthetic_batch", "synthetic_batches"]
