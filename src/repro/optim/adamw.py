"""Functional AdamW with dtype-configurable moments and global-norm clipping.

Moments are stored in ``cfg.optimizer_dtype`` (arctic-480b uses bfloat16
moments to fit the single-pod memory budget; everything else uses float32).
Moment tensors inherit their parameter's sharding, so optimizer state scales
with FSDP/TP exactly like the parameters do.

Two optimizer layouts:

* :func:`adamw_init` / :func:`adamw_update` — the replicated (DDP) layout:
  every rank holds full m/v trees and applies the full update.
* :func:`sharded_adamw_init` / :func:`sharded_adamw_update` — the ZeRO-1
  layout. State lives in FLAT BUCKET SPACE (the ``BucketPlan`` packing used
  by ``reduce_gradients``): per bucket one fp32 master-param buffer plus
  m/v moment buffers, all sharded 1/N over the data axis via a
  :class:`~repro.core.bucketing.ShardLayout`. Each rank consumes its
  reduce_scatter gradient shard directly, updates only the owned range, and
  the trainer all-gathers the *updated params* once per bucket — halving
  gradient wire bytes (reduce_scatter instead of all_reduce) and cutting
  optimizer memory 1/N. Per-leaf semantics that don't survive flattening
  (decoupled weight decay on ``ndim >= 2`` leaves only) are carried by a
  precomputed per-element mask (:func:`bucket_decay_masks`); global-norm
  clipping psums the per-shard partial sum-of-squares across ranks before
  scaling, reproducing the replicated clip exactly.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import BucketPlan, ShardLayout, pack_bucket


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState, dict]:
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        step = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# ZeRO-1: sharded AdamW in flat bucket space
# ---------------------------------------------------------------------------

class ShardedAdamWState(NamedTuple):
    """ZeRO-1 optimizer state in flat bucket space.

    ``m`` / ``v`` / ``master`` are per-bucket 1-D buffers; ``master`` is the
    fp32 master copy of the packed parameters (source of truth for the
    update — the working param tree is just its gathered, leaf-dtype view).
    Globally each buffer has the bucket's ``padded_size``; inside the
    ``shard_map`` step every rank sees only its own ``padded_size/N`` shard
    (the trainer's in/out specs put these on the data axis), so optimizer
    memory scales 1/N.
    """

    m: Tuple[jax.Array, ...]
    v: Tuple[jax.Array, ...]
    master: Tuple[jax.Array, ...]
    count: jax.Array


def bucket_decay_masks(plan: BucketPlan) -> Tuple[np.ndarray, ...]:
    """Per-bucket f32 masks carrying the per-leaf weight-decay rule into
    flat space: 1.0 on elements of ``ndim >= 2`` leaves (matrices get
    decoupled decay, exactly like :func:`adamw_update`), 0.0 on vector/
    scalar leaves and on alignment padding (padding therefore never decays
    and stays identically zero)."""
    masks = []
    for b in plan.buckets:
        mask = np.zeros((b.padded_size,), np.float32)
        for s in b.slots:
            if len(s.shape) >= 2:
                mask[s.offset:s.offset + s.size] = 1.0
        masks.append(mask)
    return tuple(masks)


def sharded_adamw_init(params, plan: BucketPlan,
                       moment_dtype=jnp.float32) -> ShardedAdamWState:
    """Build the GLOBAL ZeRO-1 state: fp32 master = the packed params, zero
    moments. Runs outside ``shard_map``; the trainer's ``P(data)`` specs
    store each buffer sharded over the data axis, so no rank ever
    materializes more than 1/N of it after placement."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    if treedef != plan.treedef:
        raise ValueError("params tree does not match the bucket plan's tree")
    master = tuple(pack_bucket(leaves, b, dtype=jnp.float32)
                   for b in plan.buckets)
    zeros = tuple(jnp.zeros((b.padded_size,), moment_dtype)
                  for b in plan.buckets)
    return ShardedAdamWState(m=zeros, v=zeros, master=master,
                             count=jnp.zeros((), jnp.int32))


def sharded_adamw_bucket_update(
    g: jax.Array,
    m: jax.Array,
    v: jax.Array,
    master: jax.Array,
    decay_mask: jax.Array,
    *,
    lr: jax.Array,
    count: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """AdamW on ONE bucket's owned shard: the bucket-granular entry point.

    ``g`` must already be mean-reduced AND clip-scaled (the global-norm
    scale is the only cross-bucket coupling in the update); ``count`` is
    the already-incremented step count. Returns ``(new_master, new_m,
    new_v)``. The whole-layout :func:`sharded_adamw_update` is a loop over
    this; the overlap trainer calls the loop with ``bucket_order =
    CommPlan.ready_order``. Note the clip scale makes every update
    data-dependent on the LAST scatter when ``max_grad_norm`` is set —
    only with clipping disabled is bucket ``b``'s update dependent on
    shard ``b`` alone, letting its param all_gather pipeline behind later
    buckets' still-running reduces.
    """
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    wd = decay_mask.astype(jnp.float32)
    mf = m.astype(jnp.float32) * b1 + g * (1 - b1)
    vf = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
    step = (mf / c1) / (jnp.sqrt(vf / c2) + eps) + weight_decay * wd * master
    return master - lr * step, mf.astype(m.dtype), vf.astype(v.dtype)


def sharded_adamw_update(
    grad_shards: Sequence[jax.Array],
    state: ShardedAdamWState,
    *,
    lr: jax.Array,
    layout: ShardLayout,
    decay_masks: Sequence[jax.Array],
    psum: Optional[Callable[[jax.Array], jax.Array]] = None,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
    bucket_order: Optional[Sequence[int]] = None,
) -> Tuple[Tuple[jax.Array, ...], ShardedAdamWState, dict]:
    """Apply AdamW to the LOCAL shard of every bucket.

    Runs inside ``shard_map``: ``grad_shards[b]`` is this rank's f32
    reduce_scatter output for bucket ``b`` (mean-reduced), ``state`` holds
    the rank's m/v/master shards, ``decay_masks[b]`` is this rank's
    SHARD-SIZED slice of :func:`bucket_decay_masks` output (hand the full
    masks to ``shard_map`` under a ``P(data)`` spec so every rank stores
    only its 1/N window, like the state buffers), and ``psum`` sums a
    scalar across ranks (the cross-shard half of global-norm clipping).
    Returns the updated fp32 param shards (for the trainer's per-bucket
    all_gather), the new state, and ``{"grad_norm": ...}``.

    ``bucket_order`` sets the per-bucket ISSUE order (default: bucket id).
    Results stay indexed by bucket id either way — each bucket's update is
    elementwise in its own shard, so order changes scheduling freedom, not
    values. Overlap trainers pass ``CommPlan.ready_order``.
    """
    if psum is None:
        psum = lambda x: x
    shard_sizes = layout.shard_sizes
    grads = [g.astype(jnp.float32) for g in grad_shards]
    for bid, (g, wd) in enumerate(zip(grads, decay_masks)):
        expect = (shard_sizes[bid],)
        if g.shape != expect or tuple(wd.shape) != expect:
            raise ValueError(
                f"bucket {bid}: grad shard {g.shape} / decay mask "
                f"{tuple(wd.shape)} do not match the layout shard {expect}")

    # global-norm clip: partial sumsq over the owned shards, psum'd. Shards
    # tile the buckets exactly (ShardLayout invariant) and padding is zero,
    # so this equals the replicated tree-wise norm up to summation order.
    sumsq = sum(jnp.sum(jnp.square(g)) for g in grads)
    gnorm = jnp.sqrt(psum(sumsq))
    if max_grad_norm is not None:
        scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-9))
        grads = [g * scale for g in grads]

    count = state.count + 1
    if bucket_order is None:
        bucket_order = range(len(grads))

    new_m: list = [None] * len(grads)
    new_v: list = [None] * len(grads)
    new_master: list = [None] * len(grads)
    for bid in bucket_order:
        new_master[bid], new_m[bid], new_v[bid] = sharded_adamw_bucket_update(
            grads[bid], state.m[bid], state.v[bid], state.master[bid],
            decay_masks[bid], lr=lr, count=count, b1=b1, b2=b2, eps=eps,
            weight_decay=weight_decay)
    new_state = ShardedAdamWState(tuple(new_m), tuple(new_v),
                                  tuple(new_master), count)
    return tuple(new_master), new_state, {"grad_norm": gnorm}
