"""Functional AdamW with dtype-configurable moments and global-norm clipping.

Moments are stored in ``cfg.optimizer_dtype`` (arctic-480b uses bfloat16
moments to fit the single-pod memory budget; everything else uses float32).
Moment tensors inherit their parameter's sharding, so optimizer state scales
with FSDP/TP exactly like the parameters do.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState, dict]:
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        step = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}
