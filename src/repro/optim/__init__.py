from repro.optim.adamw import (AdamWState, ShardedAdamWState, adamw_init,
                               adamw_update, bucket_decay_masks,
                               sharded_adamw_init, sharded_adamw_update)
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = ["AdamWState", "ShardedAdamWState", "adamw_init", "adamw_update",
           "bucket_decay_masks", "sharded_adamw_init", "sharded_adamw_update",
           "cosine_schedule", "linear_warmup"]
