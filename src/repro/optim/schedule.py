"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, *, peak: float, warmup_steps: int):
    return peak * jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))


def cosine_schedule(step, *, peak: float, warmup_steps: int, total_steps: int,
                    floor_ratio: float = 0.1):
    warm = linear_warmup(step, peak=peak, warmup_steps=warmup_steps)
    t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                 0.0, 1.0)
    cos = peak * (floor_ratio + (1 - floor_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)
