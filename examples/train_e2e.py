"""End-to-end training driver: a ~100M-param dense model for a few hundred
steps on the synthetic successor corpus, with checkpointing and the paper's
VCI gradient-communication path.

    PYTHONPATH=src python examples/train_e2e.py            # full (~100M)
    PYTHONPATH=src python examples/train_e2e.py --tiny     # CI-sized

The model is the olmo-1b family shrunk to ~100M (12 layers, d_model=768),
i.e. a *same-family* config — the framework treats it like any other entry
in the zoo.
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.io import save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.optim.schedule import cosine_schedule
from repro.train.trainer import make_train_step, train_state_init


def config_100m():
    base = get_config("olmo-1b")
    return dataclasses.replace(
        base, name="olmo-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=8192,
        dtype="float32", param_dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("olmo-1b-smoke")
        steps, batch, seq = args.steps or 30, 8, 64
    else:
        cfg = config_100m()
        steps, batch, seq = args.steps or 200, 8, 256

    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps x {batch}x{seq} tokens")

    state = train_state_init(cfg, jax.random.PRNGKey(0))
    lr = lambda s: cosine_schedule(s, peak=3e-4, warmup_steps=steps // 10,
                                   total_steps=steps)
    step = jax.jit(make_train_step(cfg, lr_fn=lr))

    t0 = time.time()
    first = last = None
    for i in range(steps):
        b = synthetic_batch(cfg, batch, seq, seed=0, step=i)
        state, m = step(state, b)
        if first is None:
            first = float(m["ce"])
        last = float(m["ce"])
        if (i + 1) % max(1, steps // 10) == 0:
            tok_s = batch * seq * (i + 1) / (time.time() - t0)
            print(f"  step {i+1:4d}  ce {last:7.4f}  "
                  f"gnorm {float(m['grad_norm']):6.3f}  tok/s {tok_s:8.0f}",
                  flush=True)

    assert np.isfinite(last)
    print(f"ce: {first:.3f} -> {last:.3f} "
          f"({100 * (1 - last / first):.0f}% reduction)")
    out = save_checkpoint(args.ckpt_dir, steps, state,
                          metadata={"arch": cfg.name, "ce": last})
    print(f"checkpoint: {out}")


if __name__ == "__main__":
    main()
