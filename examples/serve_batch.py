"""Application example — batched serving across architecture families.

Prefill + iterative decode for a dense GQA model, an attention-free SSM and
the multi-codebook audio model, through the same ServeEngine API.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def demo(arch: str, prompt_len=16, new=16, nreq=4):
    cfg = get_config(arch + "-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=2, max_len=128)
    rng = np.random.default_rng(0)
    shape = ((cfg.num_codebooks, prompt_len) if cfg.modality == "audio"
             else (prompt_len,))
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, shape,
                                        dtype=np.int32),
                    max_new_tokens=new) for _ in range(nreq)]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(r.generated.shape[-1] * (r.generated.shape[0]
                if r.generated.ndim > 1 else 1) for r in done)
    print(f"  {arch:16s} [{cfg.family:6s}] {len(done)} requests, "
          f"{n_tok} tokens, {dt:.2f}s")
    return done


def main():
    print("batched serving across families:")
    demo("yi-9b")           # dense GQA, full KV cache
    demo("mamba2-780m")     # SSM: O(1) recurrent state
    demo("zamba2-7b")       # hybrid: SSM + shared-attention KV sites
    demo("musicgen-large")  # audio: 4 codebook streams per step
    print("OK")


if __name__ == "__main__":
    main()
