"""Application example — §6.1 stencil halo exchange with VCI streams.

A 2D Jacobi iteration on a device grid: each device owns a block, halo
rows/columns travel over four independent CommContexts (the paper's odd/even
communicator sets collapse to per-direction contexts on a device grid).
Convergence is verified against the single-device reference.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/stencil_halo.py
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.compat import shard_map

ROWS = COLS = 2
BLOCK = 32
STEPS = 50


def perms():
    def at(r, c):
        return r * COLS + c
    return {
        "n": [(at(r, c), at((r - 1) % ROWS, c)) for r in range(ROWS)
              for c in range(COLS)],
        "s": [(at(r, c), at((r + 1) % ROWS, c)) for r in range(ROWS)
              for c in range(COLS)],
        "w": [(at(r, c), at(r, (c - 1) % COLS)) for r in range(ROWS)
              for c in range(COLS)],
        "e": [(at(r, c), at(r, (c + 1) % COLS)) for r in range(ROWS)
              for c in range(COLS)],
    }


def jacobi_step(u, rt, ctxs, pm):
    halos = {"n": u[:1, :], "s": u[-1:, :], "w": u[:, :1], "e": u[:, -1:]}
    recv = {d: rt.sendrecv(h, ctxs[d], axis=("y", "x"), perm=pm[d])
            for d, h in halos.items()}
    up = jnp.concatenate([recv["s"], u[:-1, :]], axis=0)
    dn = jnp.concatenate([u[1:, :], recv["n"]], axis=0)
    lf = jnp.concatenate([recv["e"], u[:, :-1]], axis=1)
    rg = jnp.concatenate([u[:, 1:], recv["w"]], axis=1)
    return 0.25 * (up + dn + lf + rg)


def reference(u0, steps):
    u = u0
    for _ in range(steps):
        up = jnp.roll(u, 1, axis=0)
        dn = jnp.roll(u, -1, axis=0)
        lf = jnp.roll(u, 1, axis=1)
        rg = jnp.roll(u, -1, axis=1)
        u = 0.25 * (up + dn + lf + rg)
    return u


def main():
    devs = jax.devices()
    if len(devs) < ROWS * COLS:
        print(f"needs {ROWS*COLS} devices; run with "
              f"XLA_FLAGS=--xla_force_host_platform_device_count={ROWS*COLS}")
        return
    mesh = Mesh(np.array(devs[: ROWS * COLS]).reshape(ROWS, COLS), ("y", "x"))
    pm = perms()

    def run(u):
        world = CommWorld(num_vcis=8)
        rt = CommRuntime(world, progress="hybrid", join_every=16,
                         token_impl="data")
        ctxs = {d: world.create(f"halo_{d}") for d in "nswe"}
        for _ in range(STEPS):
            u = jacobi_step(u, rt, ctxs, pm)
        return rt.barrier(u)

    f = jax.jit(shard_map(run, mesh=mesh, in_specs=P("y", "x"),
                          out_specs=P("y", "x"), check_vma=False))

    rng = np.random.default_rng(0)
    u0 = jnp.asarray(rng.normal(size=(ROWS * BLOCK, COLS * BLOCK)),
                     jnp.float32)
    out = f(u0)
    ref = reference(u0, STEPS)
    err = float(jnp.abs(out - ref).max())
    print(f"jacobi {STEPS} steps on {ROWS}x{COLS} devices: "
          f"max|distributed - reference| = {err:.2e}")
    assert err < 1e-4, "halo exchange incorrect"
    print("OK — VCI-stream halo exchange matches the single-device solver")


if __name__ == "__main__":
    main()
