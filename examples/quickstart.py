"""Quickstart: the public API in ~60 lines.

1. Pick an assigned architecture (reduced -smoke variant for CPU).
2. Train a few steps with the paper's VCI-bucketed gradient communication.
3. Serve a few tokens from the trained model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.optim.schedule import cosine_schedule
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import make_train_step, train_state_init


def main():
    # --- the model zoo: 10 assigned architectures, one config each --------
    cfg = get_config("gemma-2b-smoke")   # reduced same-family variant
    print(f"model: {cfg.name} ({cfg.family}), "
          f"{cfg.param_count()/1e6:.1f}M params")

    # --- train -------------------------------------------------------------
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    lr = lambda s: cosine_schedule(s, peak=1e-3, warmup_steps=5,
                                   total_steps=30)
    step = jax.jit(make_train_step(cfg, lr_fn=lr))
    for i in range(30):
        batch = synthetic_batch(cfg, batch=8, seq=64, seed=0, step=i)
        state, metrics = step(state, batch)
        if (i + 1) % 10 == 0:
            print(f"  step {i+1:3d}  loss {float(metrics['loss']):.4f}")

    # --- serve -------------------------------------------------------------
    engine = ServeEngine(cfg, state.params, batch_size=4, max_len=128)
    prompts = [Request(prompt=np.arange(16, dtype=np.int32) % cfg.vocab_size,
                       max_new_tokens=12) for _ in range(4)]
    for i, r in enumerate(engine.generate(prompts)):
        print(f"  generated[{i}]: {r.generated.tolist()}")


if __name__ == "__main__":
    main()
