"""Application example — §6.2 EBMS energy-band remote fetch.

The OpenMC energy-banding pattern: cross-section data is distributed
across nodes; every iteration each worker fetches one band shard from a
remote node with MPI_Get + MPI_Win_flush (one window per worker — the
paper's Fig. 23 parallelism) and then tracks its particles (compute).
Verifies the fetched bands match the owner's data and reports the flush
dependency structure under per-VCI vs hybrid progress.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/ebms_bands.py
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.compat import shard_map

WORKERS = 4
BAND = 4096


def main():
    devs = jax.devices()
    n = min(len(devs), 8)
    if n < 2:
        print("needs >=2 devices; run with XLA_FLAGS="
              "--xla_force_host_platform_device_count=8")
        return
    mesh = Mesh(np.array(devs[:n]), ("data",))
    perm = [(i, (i + 1) % n) for i in range(n)]  # fetch from the left node

    def make(progress):
        def step(bands):
            world = CommWorld(num_vcis=WORKERS + 1)
            rt = CommRuntime(world, progress=progress,
                             join_every=2 * WORKERS, token_impl="data")
            wins = [world.create(f"band{w}", kind="rma")
                    for w in range(WORKERS)]
            fetched = [rt.get(bands[w], wins[w], axis="data", perm=perm)
                       for w in range(WORKERS)]
            # MPI_Win_flush per worker, then the "particle tracking" compute
            flushed = [rt.flush(f, wins[w]) for w, f in enumerate(fetched)]
            tracked = [jnp.tanh(f).sum() for f in flushed]
            return rt.barrier((jnp.stack(flushed), jnp.stack(tracked)))
        return jax.jit(shard_map(step, mesh=mesh, in_specs=P(None, None),
                                 out_specs=(P(None, None), P(None)),
                                 check_vma=False))

    rng = np.random.default_rng(0)
    bands = jnp.asarray(rng.normal(size=(WORKERS, BAND)), jnp.float32)

    for progress in ("per_vci", "hybrid"):
        f = make(progress)
        fetched, tracked = f(bands)
        # every node fetched its left neighbour's band == the same global
        # band values (replicated input) — verify content integrity
        np.testing.assert_allclose(np.asarray(fetched), np.asarray(bands),
                                   rtol=1e-6)
        print(f"progress={progress:8s} fetched {WORKERS} bands x "
              f"{BAND*4/1024:.0f}KB, checksum {np.asarray(tracked).sum():.3f}")
    print("OK — EBMS remote fetch matches band owners under both progress "
          "models (TPU ICI behaves like the paper's hardware-progressed IB)")


if __name__ == "__main__":
    main()
