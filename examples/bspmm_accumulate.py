"""Application example — §6.3 BSPMM get-compute-update with the
accumulate-ordering hint.

Block-sparse matmul across devices: workers Get remote A/B tiles, multiply
locally, and Accumulate C tiles into a shared window. Demonstrates the
paper's §6.3 finding end-to-end: ``accumulate_ordering="none"`` lets the
library run accumulates on parallel streams while keeping the SAME numeric
result (the reduction is commutative).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/bspmm_accumulate.py
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import CommRuntime
from repro.core.comm import CommWorld
from repro.launch.roofline import collective_critical_depth
from repro.compat import shard_map

TILE = 64
WORKERS = 4


def main():
    devs = jax.devices()
    n = min(len(devs), 8)
    if n < 2:
        print("needs >=2 devices; run with XLA_FLAGS="
              "--xla_force_host_platform_device_count=8")
        return
    mesh = Mesh(np.array(devs[:n]), ("data",))
    perm = [(i, (i + 1) % n) for i in range(n)]

    def make(ordering):
        def step(a_tiles, b_tiles):
            world = CommWorld(num_vcis=WORKERS + 1)
            rt = CommRuntime(world, progress="hybrid",
                             join_every=4 * WORKERS, token_impl="data")
            getw = [world.create(f"g{w}", kind="rma") for w in range(WORKERS)]
            cwin = world.create("C", kind="rma",
                                accumulate_ordering=ordering)
            c = jnp.zeros((TILE, TILE), jnp.float32)
            for w in range(WORKERS):
                a = rt.get(a_tiles[w], getw[w], axis="data", perm=perm)
                b = rt.get(b_tiles[w], getw[w], axis="data", perm=perm)
                c = c + rt.accumulate(a @ b, cwin, axis="data")
            return rt.barrier(c)
        return jax.jit(shard_map(
            step, mesh=mesh, in_specs=(P(None, None, None),) * 2,
            out_specs=P(None, None), check_vma=False))

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(WORKERS, TILE, TILE)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(WORKERS, TILE, TILE)), jnp.float32)

    results = {}
    for ordering in ("rar", "none"):
        f = make(ordering)
        hlo = f.lower(a, b).compile().as_text()
        d = collective_critical_depth(hlo)
        results[ordering] = (np.asarray(f(a, b)), d)
        print(f"ordering={ordering!r}: collective critical depth "
              f"{d['critical_depth']:.0f}, parallelism {d['parallelism']:.2f}")

    np.testing.assert_allclose(results["rar"][0], results["none"][0],
                               rtol=1e-5)
    assert results["none"][1]["critical_depth"] \
        <= results["rar"][1]["critical_depth"]
    print("OK — relaxed ordering shortens the accumulate chain, values equal")


if __name__ == "__main__":
    main()
